//! Width-generic kernel bodies, written once against the [`Vf32`]
//! vector abstraction and instantiated per backend (`f32` = the scalar
//! reference, `avx2::V8`, `neon::V4`).
//!
//! ## The bitwise contract
//!
//! Every kernel here except [`dot_acc`] is **elementwise**: each output
//! lane is a fixed dag of IEEE-754 single-precision `mul`/`add`/`sub`/
//! `neg`/`max` ops on that lane's inputs, with no cross-lane
//! accumulation and no FMA contraction. Per-element IEEE arithmetic is
//! identical at any vector width, so these kernels produce **bitwise
//! identical** results on every backend — including the scalar tail a
//! vector backend runs for trailing lanes. The expression *shapes*
//! (association order of every `+`/`-`) are copied verbatim from the
//! legacy loops they replaced; changing one is a silent behaviour change
//! that `tests/kernel_conformance.rs` and the crate's bitwise
//! equivalence suites will catch.
//!
//! [`dot_acc`] is the one exception: vector backends keep `LANES`
//! partial sums (with FMA where the ISA has it) and reduce them at the
//! end, which reassociates the sum. Its contract is a documented
//! relative bound, not bitwise equality — see the function docs.

/// Minimal f32 vector abstraction. `LANES == 1` (the `f32` impl) is the
/// scalar reference; wider impls must be lane-wise IEEE-exact for
/// `add`/`sub`/`mul`/`neg` so the elementwise kernels stay bitwise
/// across backends.
pub(crate) trait Vf32: Copy {
    const LANES: usize;
    /// # Safety
    /// `p .. p + LANES` must be readable.
    unsafe fn load(p: *const f32) -> Self;
    /// # Safety
    /// `p .. p + LANES` must be writable.
    unsafe fn store(self, p: *mut f32);
    fn splat(x: f32) -> Self;
    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn mul(self, o: Self) -> Self;
    /// Exact IEEE sign flip (never `0.0 - x`).
    fn neg(self) -> Self;
    /// Lane-wise max (the relu kernel only feeds it finite data and a
    /// `+0.0` splat, where every ISA's semantics agree).
    fn vmax(self, o: Self) -> Self;
    /// `self * a + b`, contracted to FMA where the ISA has it. Used only
    /// by the dot-product family; the scalar impl is unfused on purpose.
    fn mul_add(self, a: Self, b: Self) -> Self;
    /// Lane-wise `if x > 0 { t } else { 0.0 }` where `self` is `x`.
    fn gt_zero_select(self, t: Self) -> Self;
    /// Horizontal sum, lane 0 first (left-to-right) so the reduction
    /// order is fixed per backend.
    fn hsum(self) -> f32;
}

impl Vf32 for f32 {
    const LANES: usize = 1;
    #[inline(always)]
    unsafe fn load(p: *const f32) -> f32 {
        *p
    }
    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        *p = self;
    }
    #[inline(always)]
    fn splat(x: f32) -> f32 {
        x
    }
    #[inline(always)]
    fn add(self, o: f32) -> f32 {
        self + o
    }
    #[inline(always)]
    fn sub(self, o: f32) -> f32 {
        self - o
    }
    #[inline(always)]
    fn mul(self, o: f32) -> f32 {
        self * o
    }
    #[inline(always)]
    fn neg(self) -> f32 {
        -self
    }
    #[inline(always)]
    fn vmax(self, o: f32) -> f32 {
        self.max(o)
    }
    #[inline(always)]
    fn mul_add(self, a: f32, b: f32) -> f32 {
        // unfused: the scalar backend is the bit-exactness reference for
        // the legacy `acc += w * x` loops
        self * a + b
    }
    #[inline(always)]
    fn gt_zero_select(self, t: f32) -> f32 {
        if self > 0.0 {
            t
        } else {
            0.0
        }
    }
    #[inline(always)]
    fn hsum(self) -> f32 {
        self
    }
}

/// Per-element complex 2×2 twiddles in SoA layout, one slice per
/// component — the staged form `butterfly::level` hands the span
/// kernels. All eight slices have the same length as the data spans.
pub struct TwSpan<'a> {
    pub g00r: &'a [f32],
    pub g00i: &'a [f32],
    pub g01r: &'a [f32],
    pub g01i: &'a [f32],
    pub g10r: &'a [f32],
    pub g10i: &'a [f32],
    pub g11r: &'a [f32],
    pub g11i: &'a [f32],
}

/// Mutable SoA accumulators for the twiddle gradient of one span.
pub struct TwSpanMut<'a> {
    pub g00r: &'a mut [f32],
    pub g00i: &'a mut [f32],
    pub g01r: &'a mut [f32],
    pub g01i: &'a mut [f32],
    pub g10r: &'a mut [f32],
    pub g10i: &'a mut [f32],
    pub g11r: &'a mut [f32],
    pub g11i: &'a mut [f32],
}

// ---------------------------------------------------------------------
// butterfly 2x2 stage kernels (serving layout: lanes = batch columns)
// ---------------------------------------------------------------------

/// Real 2×2 butterfly over batch lanes, in place:
/// `lo = g00·lo + g01·hi`, `hi = g10·lo₀ + g11·hi₀`.
#[inline(always)]
pub(crate) fn bf2_real<V: Vf32>(g00: f32, g01: f32, g10: f32, g11: f32, lo: &mut [f32], hi: &mut [f32]) {
    let n = lo.len();
    debug_assert_eq!(hi.len(), n);
    let (v00, v01, v10, v11) = (V::splat(g00), V::splat(g01), V::splat(g10), V::splat(g11));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let x0 = V::load(lo.as_ptr().add(k));
            let x1 = V::load(hi.as_ptr().add(k));
            v00.mul(x0).add(v01.mul(x1)).store(lo.as_mut_ptr().add(k));
            v10.mul(x0).add(v11.mul(x1)).store(hi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (x0, x1) = (lo[k], hi[k]);
        lo[k] = g00 * x0 + g01 * x1;
        hi[k] = g10 * x0 + g11 * x1;
        k += 1;
    }
}

/// Complex 2×2 butterfly over batch lanes, in place, serving shape
/// (`((a−b)+c)−d` per real part — the `fast.rs` accumulation order,
/// which a span-2 fused `KsKernel` reproduces bit for bit).
/// `g = [g00r, g00i, g01r, g01i, g10r, g10i, g11r, g11i]`.
#[inline(always)]
pub(crate) fn bf2_complex<V: Vf32>(g: &[f32; 8], rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]) {
    let n = rlo.len();
    debug_assert!(ilo.len() == n && rhi.len() == n && ihi.len() == n);
    let [g00r, g00i, g01r, g01i, g10r, g10i, g11r, g11i] = *g;
    let (v00r, v00i, v01r, v01i) = (V::splat(g00r), V::splat(g00i), V::splat(g01r), V::splat(g01i));
    let (v10r, v10i, v11r, v11i) = (V::splat(g10r), V::splat(g10i), V::splat(g11r), V::splat(g11i));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let x0r = V::load(rlo.as_ptr().add(k));
            let x0i = V::load(ilo.as_ptr().add(k));
            let x1r = V::load(rhi.as_ptr().add(k));
            let x1i = V::load(ihi.as_ptr().add(k));
            v00r.mul(x0r).sub(v00i.mul(x0i)).add(v01r.mul(x1r)).sub(v01i.mul(x1i)).store(rlo.as_mut_ptr().add(k));
            v00r.mul(x0i).add(v00i.mul(x0r)).add(v01r.mul(x1i)).add(v01i.mul(x1r)).store(ilo.as_mut_ptr().add(k));
            v10r.mul(x0r).sub(v10i.mul(x0i)).add(v11r.mul(x1r)).sub(v11i.mul(x1i)).store(rhi.as_mut_ptr().add(k));
            v10r.mul(x0i).add(v10i.mul(x0r)).add(v11r.mul(x1i)).add(v11i.mul(x1r)).store(ihi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (x0r, x0i, x1r, x1i) = (rlo[k], ilo[k], rhi[k], ihi[k]);
        rlo[k] = g00r * x0r - g00i * x0i + g01r * x1r - g01i * x1i;
        ilo[k] = g00r * x0i + g00i * x0r + g01r * x1i + g01i * x1r;
        rhi[k] = g10r * x0r - g10i * x0i + g11r * x1r - g11i * x1i;
        ihi[k] = g10r * x0i + g10i * x0r + g11r * x1i + g11i * x1r;
        k += 1;
    }
}

// ---------------------------------------------------------------------
// axpy family (ksm fused blocks, dense matvec panels)
// ---------------------------------------------------------------------

/// `out = w · x` over lanes.
#[inline(always)]
pub(crate) fn axpy_set<V: Vf32>(w: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    let wv = V::splat(w);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            wv.mul(V::load(x.as_ptr().add(k))).store(out.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        out[k] = w * x[k];
        k += 1;
    }
}

/// `out = out + w · x` over lanes (shape `o + (w·x)`, the `ksm`/`matvec`
/// accumulation order).
#[inline(always)]
pub(crate) fn axpy_acc<V: Vf32>(w: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(out.len(), n);
    let wv = V::splat(w);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let o = V::load(out.as_ptr().add(k));
            o.add(wv.mul(V::load(x.as_ptr().add(k)))).store(out.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        out[k] += w * x[k];
        k += 1;
    }
}

/// Two accumulating axpys sharing one weight: `o1 += w·x1`, `o2 += w·x2`
/// (the dense backward's `gw += g·x; dx += g·w` panel).
#[inline(always)]
pub(crate) fn axpy2_acc<V: Vf32>(w: f32, x1: &[f32], x2: &[f32], o1: &mut [f32], o2: &mut [f32]) {
    let n = x1.len();
    debug_assert!(x2.len() == n && o1.len() == n && o2.len() == n);
    let wv = V::splat(w);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let a = V::load(o1.as_ptr().add(k));
            a.add(wv.mul(V::load(x1.as_ptr().add(k)))).store(o1.as_mut_ptr().add(k));
            let b = V::load(o2.as_ptr().add(k));
            b.add(wv.mul(V::load(x2.as_ptr().add(k)))).store(o2.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        o1[k] += w * x1[k];
        o2[k] += w * x2[k];
        k += 1;
    }
}

/// Complex axpy, set form: `or = gr·xr − gi·xi`, `oi = gr·xi + gi·xr`.
#[inline(always)]
pub(crate) fn caxpy_set<V: Vf32>(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]) {
    let n = xr.len();
    debug_assert!(xi.len() == n && or_.len() == n && oi.len() == n);
    let (vr, vi) = (V::splat(gr), V::splat(gi));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let ar = V::load(xr.as_ptr().add(k));
            let ai = V::load(xi.as_ptr().add(k));
            vr.mul(ar).sub(vi.mul(ai)).store(or_.as_mut_ptr().add(k));
            vr.mul(ai).add(vi.mul(ar)).store(oi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (ar, ai) = (xr[k], xi[k]);
        or_[k] = gr * ar - gi * ai;
        oi[k] = gr * ai + gi * ar;
        k += 1;
    }
}

/// Complex axpy, accumulate form: `or = (or + gr·xr) − gi·xi`,
/// `oi = (oi + gr·xi) + gi·xr` — the `ksm` column order, which composed
/// after [`caxpy_set`] reproduces the serving butterfly bit for bit.
#[inline(always)]
pub(crate) fn caxpy_acc<V: Vf32>(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]) {
    let n = xr.len();
    debug_assert!(xi.len() == n && or_.len() == n && oi.len() == n);
    let (vr, vi) = (V::splat(gr), V::splat(gi));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let ar = V::load(xr.as_ptr().add(k));
            let ai = V::load(xi.as_ptr().add(k));
            let pr = V::load(or_.as_ptr().add(k));
            let pi = V::load(oi.as_ptr().add(k));
            pr.add(vr.mul(ar)).sub(vi.mul(ai)).store(or_.as_mut_ptr().add(k));
            pi.add(vr.mul(ai)).add(vi.mul(ar)).store(oi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (ar, ai) = (xr[k], xi[k]);
        or_[k] = or_[k] + gr * ar - gi * ai;
        oi[k] = oi[k] + gr * ai + gi * ar;
        k += 1;
    }
}

/// Complex axpy in `Cpx`-operator order: `or += (gr·xr − gi·xi)`,
/// `oi += (gr·xi + gi·xr)` — the product is reduced *before* the
/// accumulate, matching dense `CMat`/`Cpx` matvec arithmetic bit for bit
/// (contrast [`caxpy_acc`], which folds the accumulator in left to
/// right the way the `ksm` columns do).
#[inline(always)]
pub(crate) fn cmul_acc<V: Vf32>(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]) {
    let n = xr.len();
    debug_assert!(xi.len() == n && or_.len() == n && oi.len() == n);
    let (vr, vi) = (V::splat(gr), V::splat(gi));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let ar = V::load(xr.as_ptr().add(k));
            let ai = V::load(xi.as_ptr().add(k));
            let pr = V::load(or_.as_ptr().add(k));
            let pi = V::load(oi.as_ptr().add(k));
            pr.add(vr.mul(ar).sub(vi.mul(ai))).store(or_.as_mut_ptr().add(k));
            pi.add(vr.mul(ai).add(vi.mul(ar))).store(oi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (ar, ai) = (xr[k], xi[k]);
        or_[k] += gr * ar - gi * ai;
        oi[k] += gr * ai + gi * ar;
        k += 1;
    }
}

// ---------------------------------------------------------------------
// closed-form transform kernels (FFT / FWHT / DCT / DST / Hartley /
// circulant spectrum)
// ---------------------------------------------------------------------

/// One FFT butterfly row over batch lanes, in place:
/// `t = w·hi; hi = lo − t; lo = lo + t` in the `FftPlan` shape.
#[inline(always)]
pub(crate) fn fft_bf<V: Vf32>(wr: f32, wi: f32, rl: &mut [f32], il: &mut [f32], rh: &mut [f32], ih: &mut [f32]) {
    let n = rl.len();
    debug_assert!(il.len() == n && rh.len() == n && ih.len() == n);
    let (vwr, vwi) = (V::splat(wr), V::splat(wi));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let hr = V::load(rh.as_ptr().add(k));
            let hi = V::load(ih.as_ptr().add(k));
            let lr = V::load(rl.as_ptr().add(k));
            let li = V::load(il.as_ptr().add(k));
            let tr = vwr.mul(hr).sub(vwi.mul(hi));
            let ti = vwr.mul(hi).add(vwi.mul(hr));
            lr.sub(tr).store(rh.as_mut_ptr().add(k));
            li.sub(ti).store(ih.as_mut_ptr().add(k));
            lr.add(tr).store(rl.as_mut_ptr().add(k));
            li.add(ti).store(il.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let tr = wr * rh[k] - wi * ih[k];
        let ti = wr * ih[k] + wi * rh[k];
        rh[k] = rl[k] - tr;
        ih[k] = il[k] - ti;
        rl[k] += tr;
        il[k] += ti;
        k += 1;
    }
}

/// One normalized Walsh–Hadamard pair over batch lanes, in place:
/// `lo = (lo + hi)·s`, `hi = (lo₀ − hi₀)·s`.
#[inline(always)]
pub(crate) fn fwht_pair<V: Vf32>(s: f32, lo: &mut [f32], hi: &mut [f32]) {
    let n = lo.len();
    debug_assert_eq!(hi.len(), n);
    let vs = V::splat(s);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let a = V::load(lo.as_ptr().add(k));
            let b = V::load(hi.as_ptr().add(k));
            a.add(b).mul(vs).store(lo.as_mut_ptr().add(k));
            a.sub(b).mul(vs).store(hi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (a, b) = (lo[k], hi[k]);
        lo[k] = (a + b) * s;
        hi[k] = (a - b) * s;
        k += 1;
    }
}

/// In-place multiply of a complex lane row by the scalar `(hr, hi)`:
/// `re = re·hr − im·hi`, `im = re₀·hi + im₀·hr` (circulant spectrum tap).
#[inline(always)]
pub(crate) fn cmul_scalar<V: Vf32>(hr: f32, hi: f32, re: &mut [f32], im: &mut [f32]) {
    let n = re.len();
    debug_assert_eq!(im.len(), n);
    let (vhr, vhi) = (V::splat(hr), V::splat(hi));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let xr = V::load(re.as_ptr().add(k));
            let xi = V::load(im.as_ptr().add(k));
            xr.mul(vhr).sub(xi.mul(vhi)).store(re.as_mut_ptr().add(k));
            xr.mul(vhi).add(xi.mul(vhr)).store(im.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (xr, xi) = (re[k], im[k]);
        re[k] = xr * hr - xi * hi;
        im[k] = xr * hi + xi * hr;
        k += 1;
    }
}

/// `x = x · s` over lanes.
#[inline(always)]
pub(crate) fn scale<V: Vf32>(s: f32, x: &mut [f32]) {
    let n = x.len();
    let vs = V::splat(s);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            V::load(x.as_ptr().add(k)).mul(vs).store(x.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        x[k] *= s;
        k += 1;
    }
}

/// DCT/DST post-rotation row: `out = sc · ((c·vr) − (s·vi))`.
#[inline(always)]
pub(crate) fn rot_scale<V: Vf32>(c: f32, s: f32, sc: f32, vr: &[f32], vi: &[f32], out: &mut [f32]) {
    let n = vr.len();
    debug_assert!(vi.len() == n && out.len() == n);
    let (vc, vs, vsc) = (V::splat(c), V::splat(s), V::splat(sc));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let ar = V::load(vr.as_ptr().add(k));
            let ai = V::load(vi.as_ptr().add(k));
            vsc.mul(vc.mul(ar).sub(vs.mul(ai))).store(out.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        out[k] = sc * (c * vr[k] - s * vi[k]);
        k += 1;
    }
}

/// Hartley combine row: `out = (vr − vi) · s`.
#[inline(always)]
pub(crate) fn sub_scale<V: Vf32>(s: f32, vr: &[f32], vi: &[f32], out: &mut [f32]) {
    let n = vr.len();
    debug_assert!(vi.len() == n && out.len() == n);
    let vs = V::splat(s);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            V::load(vr.as_ptr().add(k)).sub(V::load(vi.as_ptr().add(k))).mul(vs).store(out.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        out[k] = (vr[k] - vi[k]) * s;
        k += 1;
    }
}

// ---------------------------------------------------------------------
// training span kernels (row-major layout: lanes = contiguous pair
// indices j within one block of one batch row; twiddles vary per lane)
// ---------------------------------------------------------------------

/// Forward complex 2×2 butterfly span with per-lane twiddles, in place,
/// training shape (`(a−b)+(c−d)` per real part — the `Cpx` operator
/// order of the legacy `level_forward`).
#[inline(always)]
pub(crate) fn bf2_cpx_span_fwd<V: Vf32>(tw: &TwSpan<'_>, rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]) {
    let n = rlo.len();
    debug_assert!(ilo.len() == n && rhi.len() == n && ihi.len() == n);
    debug_assert!(tw.g00r.len() == n && tw.g11i.len() == n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let g00r = V::load(tw.g00r.as_ptr().add(k));
            let g00i = V::load(tw.g00i.as_ptr().add(k));
            let g01r = V::load(tw.g01r.as_ptr().add(k));
            let g01i = V::load(tw.g01i.as_ptr().add(k));
            let g10r = V::load(tw.g10r.as_ptr().add(k));
            let g10i = V::load(tw.g10i.as_ptr().add(k));
            let g11r = V::load(tw.g11r.as_ptr().add(k));
            let g11i = V::load(tw.g11i.as_ptr().add(k));
            let x0r = V::load(rlo.as_ptr().add(k));
            let x0i = V::load(ilo.as_ptr().add(k));
            let x1r = V::load(rhi.as_ptr().add(k));
            let x1i = V::load(ihi.as_ptr().add(k));
            let y0r = g00r.mul(x0r).sub(g00i.mul(x0i)).add(g01r.mul(x1r).sub(g01i.mul(x1i)));
            let y0i = g00r.mul(x0i).add(g00i.mul(x0r)).add(g01r.mul(x1i).add(g01i.mul(x1r)));
            let y1r = g10r.mul(x0r).sub(g10i.mul(x0i)).add(g11r.mul(x1r).sub(g11i.mul(x1i)));
            let y1i = g10r.mul(x0i).add(g10i.mul(x0r)).add(g11r.mul(x1i).add(g11i.mul(x1r)));
            y0r.store(rlo.as_mut_ptr().add(k));
            y0i.store(ilo.as_mut_ptr().add(k));
            y1r.store(rhi.as_mut_ptr().add(k));
            y1i.store(ihi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (x0r, x0i, x1r, x1i) = (rlo[k], ilo[k], rhi[k], ihi[k]);
        let (g00r, g00i, g01r, g01i) = (tw.g00r[k], tw.g00i[k], tw.g01r[k], tw.g01i[k]);
        let (g10r, g10i, g11r, g11i) = (tw.g10r[k], tw.g10i[k], tw.g11r[k], tw.g11i[k]);
        rlo[k] = (g00r * x0r - g00i * x0i) + (g01r * x1r - g01i * x1i);
        ilo[k] = (g00r * x0i + g00i * x0r) + (g01r * x1i + g01i * x1r);
        rhi[k] = (g10r * x0r - g10i * x0i) + (g11r * x1r - g11i * x1i);
        ihi[k] = (g10r * x0i + g10i * x0r) + (g11r * x1i + g11i * x1r);
        k += 1;
    }
}

/// Backward complex 2×2 butterfly span with per-lane twiddles: one batch
/// row's contribution. Accumulates `dG += dy ⊗ conj(x)` into the SoA
/// slots (caller loops rows in batch order, preserving the legacy
/// register-accumulation order) and rewrites `d* = conj(G)ᵀ·dy` in
/// place. Conjugations go through an exact sign flip ([`Vf32::neg`]) so
/// every intermediate — including zero signs — matches the legacy `Cpx`
/// arithmetic bit for bit.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
pub(crate) fn bf2_cpx_span_bwd<V: Vf32>(
    tw: &TwSpan<'_>,
    dg: &mut TwSpanMut<'_>,
    x0r: &[f32],
    x0i: &[f32],
    x1r: &[f32],
    x1i: &[f32],
    d0r: &mut [f32],
    d0i: &mut [f32],
    d1r: &mut [f32],
    d1i: &mut [f32],
) {
    let n = x0r.len();
    debug_assert!(x1i.len() == n && d0r.len() == n && d1i.len() == n);
    debug_assert!(tw.g00r.len() == n && dg.g11i.len() == n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let a0r = V::load(x0r.as_ptr().add(k));
            let a0i = V::load(x0i.as_ptr().add(k));
            let a1r = V::load(x1r.as_ptr().add(k));
            let a1i = V::load(x1i.as_ptr().add(k));
            let e0r = V::load(d0r.as_ptr().add(k));
            let e0i = V::load(d0i.as_ptr().add(k));
            let e1r = V::load(d1r.as_ptr().add(k));
            let e1i = V::load(d1i.as_ptr().add(k));
            // dG += d ⊗ conj(x): conj(x) = (xr, −xi), product expanded
            // exactly as Cpx::mul of (d, conj(x))
            let n0i = a0i.neg();
            let n1i = a1i.neg();
            macro_rules! dg_acc {
                ($gr:expr, $gi:expr, $dr:expr, $di:expr, $xr:expr, $nxi:expr) => {{
                    let pr = $dr.mul($xr).sub($di.mul($nxi));
                    let pi = $dr.mul($nxi).add($di.mul($xr));
                    V::load($gr.as_ptr().add(k)).add(pr).store($gr.as_mut_ptr().add(k));
                    V::load($gi.as_ptr().add(k)).add(pi).store($gi.as_mut_ptr().add(k));
                }};
            }
            dg_acc!(dg.g00r, dg.g00i, e0r, e0i, a0r, n0i);
            dg_acc!(dg.g01r, dg.g01i, e0r, e0i, a1r, n1i);
            dg_acc!(dg.g10r, dg.g10i, e1r, e1i, a0r, n0i);
            dg_acc!(dg.g11r, dg.g11i, e1r, e1i, a1r, n1i);
            // dx = conj(G)ᵀ·d: conj(g) = (gr, −gi), expanded as
            // Cpx::mul(conj(g), d) then Cpx::add — the legacy shape
            let g00r = V::load(tw.g00r.as_ptr().add(k));
            let g00i = V::load(tw.g00i.as_ptr().add(k)).neg();
            let g01r = V::load(tw.g01r.as_ptr().add(k));
            let g01i = V::load(tw.g01i.as_ptr().add(k)).neg();
            let g10r = V::load(tw.g10r.as_ptr().add(k));
            let g10i = V::load(tw.g10i.as_ptr().add(k)).neg();
            let g11r = V::load(tw.g11r.as_ptr().add(k));
            let g11i = V::load(tw.g11i.as_ptr().add(k)).neg();
            let dx0r = g00r.mul(e0r).sub(g00i.mul(e0i)).add(g10r.mul(e1r).sub(g10i.mul(e1i)));
            let dx0i = g00r.mul(e0i).add(g00i.mul(e0r)).add(g10r.mul(e1i).add(g10i.mul(e1r)));
            let dx1r = g01r.mul(e0r).sub(g01i.mul(e0i)).add(g11r.mul(e1r).sub(g11i.mul(e1i)));
            let dx1i = g01r.mul(e0i).add(g01i.mul(e0r)).add(g11r.mul(e1i).add(g11i.mul(e1r)));
            dx0r.store(d0r.as_mut_ptr().add(k));
            dx0i.store(d0i.as_mut_ptr().add(k));
            dx1r.store(d1r.as_mut_ptr().add(k));
            dx1i.store(d1i.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (a0r, a0i, a1r, a1i) = (x0r[k], x0i[k], x1r[k], x1i[k]);
        let (e0r, e0i, e1r, e1i) = (d0r[k], d0i[k], d1r[k], d1i[k]);
        let (n0i, n1i) = (-a0i, -a1i);
        dg.g00r[k] += e0r * a0r - e0i * n0i;
        dg.g00i[k] += e0r * n0i + e0i * a0r;
        dg.g01r[k] += e0r * a1r - e0i * n1i;
        dg.g01i[k] += e0r * n1i + e0i * a1r;
        dg.g10r[k] += e1r * a0r - e1i * n0i;
        dg.g10i[k] += e1r * n0i + e1i * a0r;
        dg.g11r[k] += e1r * a1r - e1i * n1i;
        dg.g11i[k] += e1r * n1i + e1i * a1r;
        let (g00r, g00i) = (tw.g00r[k], -tw.g00i[k]);
        let (g01r, g01i) = (tw.g01r[k], -tw.g01i[k]);
        let (g10r, g10i) = (tw.g10r[k], -tw.g10i[k]);
        let (g11r, g11i) = (tw.g11r[k], -tw.g11i[k]);
        d0r[k] = (g00r * e0r - g00i * e0i) + (g10r * e1r - g10i * e1i);
        d0i[k] = (g00r * e0i + g00i * e0r) + (g10r * e1i + g10i * e1r);
        d1r[k] = (g01r * e0r - g01i * e0i) + (g11r * e1r - g11i * e1i);
        d1i[k] = (g01r * e0i + g01i * e0r) + (g11r * e1i + g11i * e1r);
        k += 1;
    }
}

// ---------------------------------------------------------------------
// nn layer kernels
// ---------------------------------------------------------------------

/// `y = max(x, 0)` over lanes.
#[inline(always)]
pub(crate) fn relu_fwd<V: Vf32>(x: &[f32], y: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(y.len(), n);
    let zero = V::splat(0.0);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            V::load(x.as_ptr().add(k)).vmax(zero).store(y.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        y[k] = x[k].max(0.0);
        k += 1;
    }
}

/// `dx = dy ⊙ [x > 0]` over lanes.
#[inline(always)]
pub(crate) fn relu_bwd<V: Vf32>(x: &[f32], dy: &[f32], dx: &mut [f32]) {
    let n = dx.len();
    debug_assert!(x.len() >= n && dy.len() >= n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            V::load(x.as_ptr().add(k))
                .gt_zero_select(V::load(dy.as_ptr().add(k)))
                .store(dx.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        dx[k] = if x[k] > 0.0 { dy[k] } else { 0.0 };
        k += 1;
    }
}

/// Momentum-SGD parameter update over lanes:
/// `v = momentum·v + g + wd·p; p = p − lr·v`.
#[inline(always)]
pub(crate) fn sgd_step<V: Vf32>(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, momentum: f32, wd: f32) {
    let n = p.len();
    debug_assert!(v.len() == n && g.len() == n);
    let (vlr, vmom, vwd) = (V::splat(lr), V::splat(momentum), V::splat(wd));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let pv = V::load(p.as_ptr().add(k));
            let vv = V::load(v.as_ptr().add(k));
            let gv = V::load(g.as_ptr().add(k));
            let nv = vmom.mul(vv).add(gv).add(vwd.mul(pv));
            nv.store(v.as_mut_ptr().add(k));
            pv.sub(vlr.mul(nv)).store(p.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        v[k] = momentum * v[k] + g[k] + wd * p[k];
        p[k] -= lr * v[k];
        k += 1;
    }
}

/// Masked momentum-SGD update over lanes (butterfly layers: the mask
/// pins imaginary planes of real modules and fixed-permutation logits):
/// `v = momentum·v + (g + wd·p)·m; p = p − lr·v`.
#[inline(always)]
pub(crate) fn masked_sgd_step<V: Vf32>(
    p: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    m: &[f32],
    lr: f32,
    momentum: f32,
    wd: f32,
) {
    let n = p.len();
    debug_assert!(v.len() == n && g.len() == n && m.len() == n);
    let (vlr, vmom, vwd) = (V::splat(lr), V::splat(momentum), V::splat(wd));
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let pv = V::load(p.as_ptr().add(k));
            let vv = V::load(v.as_ptr().add(k));
            let gv = V::load(g.as_ptr().add(k));
            let mv = V::load(m.as_ptr().add(k));
            let gi = gv.add(vwd.mul(pv)).mul(mv);
            let nv = vmom.mul(vv).add(gi);
            nv.store(v.as_mut_ptr().add(k));
            pv.sub(vlr.mul(nv)).store(p.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let gi = (g[k] + wd * p[k]) * m[k];
        v[k] = momentum * v[k] + gi;
        p[k] -= lr * v[k];
        k += 1;
    }
}

/// Plain accumulate over lanes: `out += x` (bias gradients, `dh` sums).
#[inline(always)]
pub(crate) fn add_acc<V: Vf32>(x: &[f32], out: &mut [f32]) {
    let n = out.len();
    debug_assert!(x.len() >= n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let o = V::load(out.as_ptr().add(k));
            o.add(V::load(x.as_ptr().add(k))).store(out.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        out[k] += x[k];
        k += 1;
    }
}

/// In-place elementwise complex Hadamard product `x ← h ∘ x`:
/// `xr = hr·xr − hi·xi`, `xi = hr·xi + hi·xr` (circulant spectra).
#[inline(always)]
pub(crate) fn cmul_ew<V: Vf32>(hr: &[f32], hi: &[f32], xr: &mut [f32], xi: &mut [f32]) {
    let n = xr.len();
    debug_assert!(hr.len() >= n && hi.len() >= n && xi.len() == n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let hrv = V::load(hr.as_ptr().add(k));
            let hiv = V::load(hi.as_ptr().add(k));
            let a = V::load(xr.as_ptr().add(k));
            let b = V::load(xi.as_ptr().add(k));
            hrv.mul(a).sub(hiv.mul(b)).store(xr.as_mut_ptr().add(k));
            hrv.mul(b).add(hiv.mul(a)).store(xi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        let (a, b) = (xr[k], xi[k]);
        xr[k] = hr[k] * a - hi[k] * b;
        xi[k] = hr[k] * b + hi[k] * a;
        k += 1;
    }
}

/// Out-of-place elementwise conjugate Hadamard product `o = conj(h) ∘ x`:
/// `or = hr·xr + hi·xi`, `oi = hr·xi − hi·xr` (circulant backward).
#[inline(always)]
pub(crate) fn cmulc_ew<V: Vf32>(hr: &[f32], hi: &[f32], xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]) {
    let n = or_.len();
    debug_assert!(hr.len() >= n && hi.len() >= n && xr.len() >= n && xi.len() >= n && oi.len() == n);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            let hrv = V::load(hr.as_ptr().add(k));
            let hiv = V::load(hi.as_ptr().add(k));
            let a = V::load(xr.as_ptr().add(k));
            let b = V::load(xi.as_ptr().add(k));
            hrv.mul(a).add(hiv.mul(b)).store(or_.as_mut_ptr().add(k));
            hrv.mul(b).sub(hiv.mul(a)).store(oi.as_mut_ptr().add(k));
        }
        k += V::LANES;
    }
    while k < n {
        or_[k] = hr[k] * xr[k] + hi[k] * xi[k];
        oi[k] = hr[k] * xi[k] - hi[k] * xr[k];
        k += 1;
    }
}

/// Dot product with running init: scalar backend computes the exact
/// legacy `acc = init; acc += a[i]·b[i]` chain; vector backends keep
/// `LANES` FMA partial sums reduced left-to-right, then add `init` and
/// the scalar tail. The reassociation moves the result by
/// `≲ len·ε·Σ|aᵢ·bᵢ|` relative to scalar — the one non-bitwise kernel
/// (see `tests/kernel_conformance.rs` for the enforced bound).
#[inline(always)]
pub(crate) fn dot_acc<V: Vf32>(init: f32, a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    debug_assert_eq!(b.len(), n);
    if V::LANES == 1 || n < V::LANES {
        let mut acc = init;
        for k in 0..n {
            acc += a[k] * b[k];
        }
        return acc;
    }
    let mut accv = V::splat(0.0);
    let mut k = 0;
    while k + V::LANES <= n {
        unsafe {
            accv = V::load(a.as_ptr().add(k)).mul_add(V::load(b.as_ptr().add(k)), accv);
        }
        k += V::LANES;
    }
    let mut acc = init + accv.hsum();
    while k < n {
        acc += a[k] * b[k];
        k += 1;
    }
    acc
}

// ---------------------------------------------------------------------
// permutation gate (gather-bound — scalar on every backend)
// ---------------------------------------------------------------------

/// One relaxed-permutation gate blend over a contiguous block of one
/// batch row: `out[i] = p·x[table[i]] + q·x[i]`. The `table` gather is
/// data-dependent random access, so no backend vectorizes it — routing
/// it through `kernels` keeps the dispatch story complete (and leaves a
/// single place to add an ISA gather later).
#[inline(always)]
pub(crate) fn gate_blend(p: f32, q: f32, x: &[f32], table: &[usize], out: &mut [f32]) {
    debug_assert!(out.len() == table.len() && x.len() == table.len());
    for (i, &ti) in table.iter().enumerate() {
        out[i] = p * x[ti] + q * x[i];
    }
}
