//! AVX2/FMA instantiation of the generic kernel bodies (x86-64 only).
//!
//! Each public function is a thin `#[target_feature(enable = "avx2,fma")]`
//! wrapper that monomorphizes the matching `generic::*` body over
//! [`V8`] (8 × f32 in a `__m256`). The wrappers are `unsafe` to call:
//! the caller (the dispatch macro in `kernels::mod`) must have verified
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//! first. Inside the wrapper the compiler may assume AVX2+FMA, which is
//! what lets the `#[inline(always)]` generic bodies compile to real
//! vector code.
//!
//! Only [`V8::mul_add`] emits FMA — the elementwise kernels use plain
//! `vmulps`/`vaddps`/`vsubps`/`vxorps` so their results stay bitwise
//! identical to the scalar reference (see the contract in
//! `kernels::generic`).
#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

use super::generic::{self, TwSpan, TwSpanMut, Vf32};

/// 8-lane f32 vector backed by a `__m256`.
///
/// Every method is only called from inside a `target_feature(avx2,fma)`
/// wrapper, so the intrinsics are in scope feature-wise; the `unsafe`
/// blocks discharge the raw-pointer obligations of `load`/`store` and
/// the target-feature obligation rustc still tracks on non-`target_feature`
/// inline contexts.
#[derive(Clone, Copy)]
pub(crate) struct V8(__m256);

impl Vf32 for V8 {
    const LANES: usize = 8;
    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        V8(_mm256_loadu_ps(p))
    }
    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        _mm256_storeu_ps(p, self.0)
    }
    #[inline(always)]
    fn splat(x: f32) -> Self {
        unsafe { V8(_mm256_set1_ps(x)) }
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        unsafe { V8(_mm256_add_ps(self.0, o.0)) }
    }
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        unsafe { V8(_mm256_sub_ps(self.0, o.0)) }
    }
    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        unsafe { V8(_mm256_mul_ps(self.0, o.0)) }
    }
    #[inline(always)]
    fn neg(self) -> Self {
        // exact IEEE sign flip via xor with the sign-bit mask (never
        // `0.0 - x`, which differs on signed zeros)
        unsafe { V8(_mm256_xor_ps(self.0, _mm256_set1_ps(-0.0))) }
    }
    #[inline(always)]
    fn vmax(self, o: Self) -> Self {
        unsafe { V8(_mm256_max_ps(self.0, o.0)) }
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        // fused: only the dot-product family calls this, under its
        // documented (non-bitwise) accuracy contract
        unsafe { V8(_mm256_fmadd_ps(self.0, a.0, b.0)) }
    }
    #[inline(always)]
    fn gt_zero_select(self, t: Self) -> Self {
        unsafe {
            let mask = _mm256_cmp_ps::<_CMP_GT_OQ>(self.0, _mm256_setzero_ps());
            V8(_mm256_and_ps(mask, t.0))
        }
    }
    #[inline(always)]
    fn hsum(self) -> f32 {
        // fixed left-to-right lane order so the reduction is
        // deterministic for a given backend
        let mut lanes = [0.0f32; 8];
        unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), self.0) };
        let mut acc = lanes[0];
        for &l in &lanes[1..] {
            acc += l;
        }
        acc
    }
}

macro_rules! avx2_wrap {
    ($(fn $name:ident($($arg:ident: $ty:ty),* $(,)?) $(-> $ret:ty)?;)*) => {
        $(
            /// # Safety
            /// Caller must have verified AVX2 + FMA are available on the
            /// running CPU (the dispatch layer does).
            #[target_feature(enable = "avx2,fma")]
            pub(crate) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                generic::$name::<V8>($($arg),*)
            }
        )*
    };
}

avx2_wrap! {
    fn bf2_real(g00: f32, g01: f32, g10: f32, g11: f32, lo: &mut [f32], hi: &mut [f32]);
    fn bf2_complex(g: &[f32; 8], rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]);
    fn axpy_set(w: f32, x: &[f32], out: &mut [f32]);
    fn axpy_acc(w: f32, x: &[f32], out: &mut [f32]);
    fn axpy2_acc(w: f32, x1: &[f32], x2: &[f32], o1: &mut [f32], o2: &mut [f32]);
    fn caxpy_set(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn caxpy_acc(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn cmul_acc(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn fft_bf(wr: f32, wi: f32, rl: &mut [f32], il: &mut [f32], rh: &mut [f32], ih: &mut [f32]);
    fn fwht_pair(s: f32, lo: &mut [f32], hi: &mut [f32]);
    fn cmul_scalar(hr: f32, hi: f32, re: &mut [f32], im: &mut [f32]);
    fn scale(s: f32, x: &mut [f32]);
    fn rot_scale(c: f32, s: f32, sc: f32, vr: &[f32], vi: &[f32], out: &mut [f32]);
    fn sub_scale(s: f32, vr: &[f32], vi: &[f32], out: &mut [f32]);
    fn relu_fwd(x: &[f32], y: &mut [f32]);
    fn relu_bwd(x: &[f32], dy: &[f32], dx: &mut [f32]);
    fn sgd_step(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, momentum: f32, wd: f32);
    fn masked_sgd_step(p: &mut [f32], v: &mut [f32], g: &[f32], m: &[f32], lr: f32, momentum: f32, wd: f32);
    fn add_acc(x: &[f32], out: &mut [f32]);
    fn cmul_ew(hr: &[f32], hi: &[f32], xr: &mut [f32], xi: &mut [f32]);
    fn cmulc_ew(hr: &[f32], hi: &[f32], xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn dot_acc(init: f32, a: &[f32], b: &[f32]) -> f32;
}

/// # Safety
/// Caller must have verified AVX2 + FMA are available.
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn bf2_cpx_span_fwd(tw: &TwSpan<'_>, rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]) {
    generic::bf2_cpx_span_fwd::<V8>(tw, rlo, ilo, rhi, ihi)
}

/// # Safety
/// Caller must have verified AVX2 + FMA are available.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn bf2_cpx_span_bwd(
    tw: &TwSpan<'_>,
    dg: &mut TwSpanMut<'_>,
    x0r: &[f32],
    x0i: &[f32],
    x1r: &[f32],
    x1i: &[f32],
    d0r: &mut [f32],
    d0i: &mut [f32],
    d1r: &mut [f32],
    d1i: &mut [f32],
) {
    generic::bf2_cpx_span_bwd::<V8>(tw, dg, x0r, x0i, x1r, x1i, d0r, d0i, d1r, d1i)
}
