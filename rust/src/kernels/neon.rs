//! NEON instantiation of the generic kernel bodies (aarch64 only).
//!
//! NEON is mandatory on aarch64, so unlike the AVX2 path there is no
//! runtime feature check to make — the wrappers still mirror the
//! `target_feature` shape so all backends go through the same dispatch
//! macro. Only [`V4::mul_add`] emits FMA (`vfmaq_f32`); everything else
//! is plain lane-wise IEEE arithmetic, keeping the elementwise kernels
//! bitwise identical to the scalar reference.
#![cfg(target_arch = "aarch64")]

use core::arch::aarch64::*;

use super::generic::{self, TwSpan, TwSpanMut, Vf32};

/// 4-lane f32 vector backed by a `float32x4_t`.
#[derive(Clone, Copy)]
pub(crate) struct V4(float32x4_t);

impl Vf32 for V4 {
    const LANES: usize = 4;
    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        V4(vld1q_f32(p))
    }
    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        vst1q_f32(p, self.0)
    }
    #[inline(always)]
    fn splat(x: f32) -> Self {
        unsafe { V4(vdupq_n_f32(x)) }
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        unsafe { V4(vaddq_f32(self.0, o.0)) }
    }
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        unsafe { V4(vsubq_f32(self.0, o.0)) }
    }
    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        unsafe { V4(vmulq_f32(self.0, o.0)) }
    }
    #[inline(always)]
    fn neg(self) -> Self {
        // vnegq is an exact IEEE sign flip
        unsafe { V4(vnegq_f32(self.0)) }
    }
    #[inline(always)]
    fn vmax(self, o: Self) -> Self {
        unsafe { V4(vmaxq_f32(self.0, o.0)) }
    }
    #[inline(always)]
    fn mul_add(self, a: Self, b: Self) -> Self {
        // vfmaq_f32(acc, x, y) = acc + x*y, fused; dot-product family only
        unsafe { V4(vfmaq_f32(b.0, self.0, a.0)) }
    }
    #[inline(always)]
    fn gt_zero_select(self, t: Self) -> Self {
        unsafe {
            let mask = vcgtq_f32(self.0, vdupq_n_f32(0.0));
            V4(vreinterpretq_f32_u32(vandq_u32(mask, vreinterpretq_u32_f32(t.0))))
        }
    }
    #[inline(always)]
    fn hsum(self) -> f32 {
        // fixed left-to-right lane order for a deterministic reduction
        let mut lanes = [0.0f32; 4];
        unsafe { vst1q_f32(lanes.as_mut_ptr(), self.0) };
        let mut acc = lanes[0];
        for &l in &lanes[1..] {
            acc += l;
        }
        acc
    }
}

macro_rules! neon_wrap {
    ($(fn $name:ident($($arg:ident: $ty:ty),* $(,)?) $(-> $ret:ty)?;)*) => {
        $(
            /// # Safety
            /// NEON is baseline on aarch64; `unsafe` is kept for dispatch
            /// symmetry with the AVX2 wrappers.
            #[target_feature(enable = "neon")]
            pub(crate) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                generic::$name::<V4>($($arg),*)
            }
        )*
    };
}

neon_wrap! {
    fn bf2_real(g00: f32, g01: f32, g10: f32, g11: f32, lo: &mut [f32], hi: &mut [f32]);
    fn bf2_complex(g: &[f32; 8], rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]);
    fn axpy_set(w: f32, x: &[f32], out: &mut [f32]);
    fn axpy_acc(w: f32, x: &[f32], out: &mut [f32]);
    fn axpy2_acc(w: f32, x1: &[f32], x2: &[f32], o1: &mut [f32], o2: &mut [f32]);
    fn caxpy_set(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn caxpy_acc(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn cmul_acc(gr: f32, gi: f32, xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn fft_bf(wr: f32, wi: f32, rl: &mut [f32], il: &mut [f32], rh: &mut [f32], ih: &mut [f32]);
    fn fwht_pair(s: f32, lo: &mut [f32], hi: &mut [f32]);
    fn cmul_scalar(hr: f32, hi: f32, re: &mut [f32], im: &mut [f32]);
    fn scale(s: f32, x: &mut [f32]);
    fn rot_scale(c: f32, s: f32, sc: f32, vr: &[f32], vi: &[f32], out: &mut [f32]);
    fn sub_scale(s: f32, vr: &[f32], vi: &[f32], out: &mut [f32]);
    fn relu_fwd(x: &[f32], y: &mut [f32]);
    fn relu_bwd(x: &[f32], dy: &[f32], dx: &mut [f32]);
    fn sgd_step(p: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, momentum: f32, wd: f32);
    fn masked_sgd_step(p: &mut [f32], v: &mut [f32], g: &[f32], m: &[f32], lr: f32, momentum: f32, wd: f32);
    fn add_acc(x: &[f32], out: &mut [f32]);
    fn cmul_ew(hr: &[f32], hi: &[f32], xr: &mut [f32], xi: &mut [f32]);
    fn cmulc_ew(hr: &[f32], hi: &[f32], xr: &[f32], xi: &[f32], or_: &mut [f32], oi: &mut [f32]);
    fn dot_acc(init: f32, a: &[f32], b: &[f32]) -> f32;
}

/// # Safety
/// NEON is baseline on aarch64; kept `unsafe` for dispatch symmetry.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn bf2_cpx_span_fwd(tw: &TwSpan<'_>, rlo: &mut [f32], ilo: &mut [f32], rhi: &mut [f32], ihi: &mut [f32]) {
    generic::bf2_cpx_span_fwd::<V4>(tw, rlo, ilo, rhi, ihi)
}

/// # Safety
/// NEON is baseline on aarch64; kept `unsafe` for dispatch symmetry.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn bf2_cpx_span_bwd(
    tw: &TwSpan<'_>,
    dg: &mut TwSpanMut<'_>,
    x0r: &[f32],
    x0i: &[f32],
    x1r: &[f32],
    x1i: &[f32],
    d0r: &mut [f32],
    d0i: &mut [f32],
    d1r: &mut [f32],
    d1i: &mut [f32],
) {
    generic::bf2_cpx_span_bwd::<V4>(tw, dg, x0r, x0i, x1r, x1i, d0r, d0i, d1r, d1i)
}
