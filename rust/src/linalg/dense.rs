//! Dense real and complex matrices.
//!
//! `Mat` is a row-major real `f32` matrix; `CMat` is a complex matrix in
//! *planar* layout (separate contiguous `re`/`im` planes), matching the
//! `[2, rows, cols]` real-pair tensors exchanged with the JAX layer. Both
//! are deliberately simple — the heavy lifting in this library happens in
//! the structured (butterfly / FFT) paths, and the dense paths serve as
//! targets, baselines, and oracles.

use crate::linalg::complex::Cpx;

/// Row-major dense real matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// y = A x (naive GEMV; the baseline the paper benchmarks against).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// GEMV into a preallocated buffer.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
    }

    /// C = A B (blocked ikj GEMM — cache-friendly; used by baselines and
    /// the dense comparison rows of the speed benchmark).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Mat::zeros(m, n);
        const BK: usize = 64;
        for kk in (0..k).step_by(BK) {
            let kend = (kk + BK).min(k);
            for i in 0..m {
                for p in kk..kend {
                    let a = self.data[i * k + p];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[p * n..(p + 1) * n];
                    let crow = &mut c.data[i * n..(i + 1) * n];
                    for j in 0..n {
                        crow[j] += a * brow[j];
                    }
                }
            }
        }
        c
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Promote to a complex matrix with zero imaginary plane.
    pub fn to_cmat(&self) -> CMat {
        CMat {
            rows: self.rows,
            cols: self.cols,
            re: self.data.clone(),
            im: vec![0.0; self.data.len()],
        }
    }
}

/// Planar complex matrix: `re` and `im` are each row-major `rows×cols`.
#[derive(Debug, Clone, PartialEq)]
pub struct CMat {
    pub rows: usize,
    pub cols: usize,
    pub re: Vec<f32>,
    pub im: Vec<f32>,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMat {
            rows,
            cols,
            re: vec![0.0; rows * cols],
            im: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = CMat::zeros(n, n);
        for i in 0..n {
            m.re[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> Cpx) -> Self {
        let mut m = CMat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                let z = f(i, j);
                m.re[i * cols + j] = z.re;
                m.im[i * cols + j] = z.im;
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> Cpx {
        let k = i * self.cols + j;
        Cpx::new(self.re[k], self.im[k])
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, z: Cpx) {
        let k = i * self.cols + j;
        self.re[k] = z.re;
        self.im[k] = z.im;
    }

    /// Batched `Y = A X` over planar row-major `[batch, cols]` inputs,
    /// returning `[batch, rows]` planes. The dense O(N²) reference for
    /// the batched fast-multiply equivalence tests.
    pub fn matvec_batch_planar(&self, xre: &[f32], xim: &[f32], batch: usize) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(xre.len(), batch * self.cols);
        assert_eq!(xim.len(), batch * self.cols);
        let mut yre = vec![0.0f32; batch * self.rows];
        let mut yim = vec![0.0f32; batch * self.rows];
        for b in 0..batch {
            let xoff = b * self.cols;
            for i in 0..self.rows {
                let base = i * self.cols;
                let mut acc = Cpx::ZERO;
                for j in 0..self.cols {
                    acc += Cpx::new(self.re[base + j], self.im[base + j])
                        * Cpx::new(xre[xoff + j], xim[xoff + j]);
                }
                yre[b * self.rows + i] = acc.re;
                yim[b * self.rows + i] = acc.im;
            }
        }
        (yre, yim)
    }

    /// y = A x over complex scalars.
    pub fn matvec(&self, x: &[Cpx]) -> Vec<Cpx> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![Cpx::ZERO; self.rows];
        for i in 0..self.rows {
            let mut acc = Cpx::ZERO;
            let base = i * self.cols;
            for j in 0..self.cols {
                acc += Cpx::new(self.re[base + j], self.im[base + j]) * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// C = A B over complex scalars.
    pub fn matmul(&self, other: &CMat) -> CMat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = CMat::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.at(i, p);
                if a.re == 0.0 && a.im == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let idx = p * n + j;
                    let b = Cpx::new(other.re[idx], other.im[idx]);
                    let prod = a * b;
                    let cidx = i * n + j;
                    c.re[cidx] += prod.re;
                    c.im[cidx] += prod.im;
                }
            }
        }
        c
    }

    pub fn sub(&self, other: &CMat) -> CMat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        CMat {
            rows: self.rows,
            cols: self.cols,
            re: self.re.iter().zip(&other.re).map(|(a, b)| a - b).collect(),
            im: self.im.iter().zip(&other.im).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn conj_transpose(&self) -> CMat {
        CMat::from_fn(self.cols, self.rows, |i, j| self.at(j, i).conj())
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.re
            .iter()
            .zip(self.im.iter())
            .map(|(&r, &i)| (r as f64) * (r as f64) + (i as f64) * (i as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Paper's RMSE: (1/N)‖T − M‖_F for N×N matrices — i.e. the
    /// root-mean-square of entrywise error.
    pub fn rmse_to(&self, other: &CMat) -> f64 {
        let d = self.sub(other);
        d.frobenius_norm() / ((self.rows as f64) * (self.cols as f64)).sqrt()
    }

    /// Pack into the `[2, rows, cols]` real-pair layout (re plane then im
    /// plane) used by the AOT artifacts.
    pub fn to_planar(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(2 * self.re.len());
        out.extend_from_slice(&self.re);
        out.extend_from_slice(&self.im);
        out
    }

    /// Inverse of [`to_planar`].
    pub fn from_planar(rows: usize, cols: usize, planar: &[f32]) -> Self {
        assert_eq!(planar.len(), 2 * rows * cols);
        CMat {
            rows,
            cols,
            re: planar[..rows * cols].to_vec(),
            im: planar[rows * cols..].to_vec(),
        }
    }

    /// Maximum entrywise modulus of the difference.
    pub fn max_abs_diff(&self, other: &CMat) -> f32 {
        let mut best = 0.0f32;
        for (a, b) in self
            .re
            .iter()
            .zip(self.im.iter())
            .zip(other.re.iter().zip(other.im.iter()))
        {
            let d = Cpx::new(a.0 - b.0, a.1 - b.1).abs();
            best = best.max(d);
        }
        best
    }

    /// The real part as a `Mat`.
    pub fn real(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.re.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_matvec_is_identity() {
        let a = Mat::eye(5);
        let x: Vec<f32> = (0..5).map(|i| i as f32).collect();
        assert_eq!(a.matvec(&x), x);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn blocked_gemm_matches_naive_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(33);
        let m = Mat::from_fn(70, 65, |_, _| rng.normal_f32(0.0, 1.0));
        let n = Mat::from_fn(65, 80, |_, _| rng.normal_f32(0.0, 1.0));
        let c = m.matmul(&n);
        // naive check on a few entries
        for &(i, j) in &[(0usize, 0usize), (69, 79), (35, 40)] {
            let mut acc = 0.0f64;
            for p in 0..65 {
                acc += m.at(i, p) as f64 * n.at(p, j) as f64;
            }
            assert!((c.at(i, j) as f64 - acc).abs() < 1e-3);
        }
    }

    #[test]
    fn cmat_matvec_complex() {
        // [[i, 0],[0, -i]] * [1, i] = [i, 1]  (since -i * i = 1)
        let a = CMat::from_fn(2, 2, |i, j| {
            if i == j {
                if i == 0 {
                    Cpx::I
                } else {
                    -Cpx::I
                }
            } else {
                Cpx::ZERO
            }
        });
        let y = a.matvec(&[Cpx::ONE, Cpx::I]);
        assert!((y[0] - Cpx::I).abs() < 1e-7);
        assert!((y[1] - Cpx::ONE).abs() < 1e-7);
    }

    #[test]
    fn planar_roundtrip() {
        let a = CMat::from_fn(3, 4, |i, j| Cpx::new(i as f32, j as f32));
        let p = a.to_planar();
        assert_eq!(p.len(), 24);
        let b = CMat::from_planar(3, 4, &p);
        assert_eq!(a, b);
    }

    #[test]
    fn rmse_scale() {
        let a = CMat::zeros(4, 4);
        let mut b = CMat::zeros(4, 4);
        for k in 0..16 {
            b.re[k] = 2.0;
        }
        // RMSE of constant-2 error is 2.
        assert!((a.rmse_to(&b) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn conj_transpose_involution() {
        let a = CMat::from_fn(3, 5, |i, j| Cpx::new(i as f32 - 1.0, j as f32 + 0.5));
        let b = a.conj_transpose().conj_transpose();
        assert_eq!(a, b);
    }

    #[test]
    fn cmat_matmul_identity() {
        let a = CMat::from_fn(4, 4, |i, j| Cpx::new((i * 4 + j) as f32, -(j as f32)));
        let c = a.matmul(&CMat::eye(4));
        assert!(a.max_abs_diff(&c) < 1e-6);
    }
}
