//! Dense linear-algebra substrate: complex scalars, real/complex matrices,
//! GEMM/GEMV, Frobenius norms, and a from-scratch Jacobi SVD.
//!
//! These are the *unstructured* code paths of the library — they provide
//! the transform targets, the compression baselines, and the oracles the
//! structured (butterfly / FFT) paths are tested against.

pub mod complex;
pub mod dense;
pub mod svd;

pub use complex::Cpx;
pub use dense::{CMat, Mat};
pub use svd::{low_rank_approx, svd_complex, svd_real, SvdC, SvdR};
