//! Singular value decomposition via one-sided Jacobi, for real and complex
//! matrices. No LAPACK in the sandbox, so this is built from scratch; it is
//! used by the low-rank and robust-PCA baselines of the Figure 3 comparison.
//!
//! One-sided Jacobi repeatedly applies plane rotations on the *right* of A
//! until all column pairs are numerically orthogonal; then
//! `σ_j = ‖a_j‖`, `u_j = a_j/σ_j`, and the accumulated rotations form V.
//! Internally f64 for convergence; inputs/outputs are f32.

use crate::linalg::dense::{CMat, Mat};

/// Complex f64 helper local to the SVD (the public `Cpx` is f32).
#[derive(Clone, Copy, Debug, Default)]
struct C64 {
    re: f64,
    im: f64,
}

impl C64 {
    fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }
    fn conj(self) -> Self {
        C64::new(self.re, -self.im)
    }
    fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
    fn mul(self, o: C64) -> Self {
        C64::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
    fn scale(self, s: f64) -> Self {
        C64::new(self.re * s, self.im * s)
    }
    fn add(self, o: C64) -> Self {
        C64::new(self.re + o.re, self.im + o.im)
    }
    fn sub(self, o: C64) -> Self {
        C64::new(self.re - o.re, self.im - o.im)
    }
}

/// Result of a complex SVD: `A = U · diag(s) · Vh` with `U: m×r`,
/// `s: r` (descending), `Vh: r×n`, `r = min(m, n)`.
#[derive(Debug, Clone)]
pub struct SvdC {
    pub u: CMat,
    pub s: Vec<f32>,
    pub vh: CMat,
}

/// Result of a real SVD.
#[derive(Debug, Clone)]
pub struct SvdR {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

/// Column-major f64 working copy of a complex matrix.
struct Work {
    m: usize,
    n: usize,
    /// cols[j][i] — column-major for cache-friendly column ops.
    cols: Vec<Vec<C64>>,
}

impl Work {
    fn from_cmat(a: &CMat) -> Self {
        let (m, n) = (a.rows, a.cols);
        let mut cols = vec![vec![C64::default(); m]; n];
        for j in 0..n {
            for i in 0..m {
                let k = i * n + j;
                cols[j][i] = C64::new(a.re[k] as f64, a.im[k] as f64);
            }
        }
        Work { m, n, cols }
    }
}

/// One-sided Jacobi SVD of a complex matrix.
///
/// Handles m ≥ n directly; for m < n we decompose the conjugate transpose
/// and swap roles of U and V.
pub fn svd_complex(a: &CMat) -> SvdC {
    if a.rows < a.cols {
        let t = svd_complex(&a.conj_transpose());
        // A^H = U Σ V^H  ⇒  A = V Σ U^H.
        return SvdC {
            u: t.vh.conj_transpose(),
            s: t.s,
            vh: t.u.conj_transpose(),
        };
    }
    let mut w = Work::from_cmat(a);
    let (m, n) = (w.m, w.n);
    // V accumulator (n×n), column-major.
    let mut v = vec![vec![C64::default(); n]; n];
    for (j, col) in v.iter_mut().enumerate() {
        col[j] = C64::new(1.0, 0.0);
    }

    let eps = 1e-14f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p, q) column pair.
                let mut alpha = 0.0f64; // ‖a_p‖²
                let mut beta = 0.0f64; // ‖a_q‖²
                let mut gamma = C64::default(); // a_p^H a_q
                for i in 0..m {
                    let ap = w.cols[p][i];
                    let aq = w.cols[q][i];
                    alpha += ap.re * ap.re + ap.im * ap.im;
                    beta += aq.re * aq.re + aq.im * aq.im;
                    gamma = gamma.add(ap.conj().mul(aq));
                }
                let g = gamma.abs();
                if g <= eps * (alpha * beta).sqrt() || alpha == 0.0 || beta == 0.0 {
                    continue;
                }
                off += g;
                // Complex Jacobi rotation (Forsythe–Henrici form):
                // phase e^{iφ} = γ/|γ|; rotation angle θ from the real
                // 2×2 symmetric problem [[α, |γ|], [|γ|, β]].
                let phase = C64::new(gamma.re / g, gamma.im / g);
                let zeta = (beta - alpha) / (2.0 * g);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Columns update: [a_p, a_q] ← [c·a_p − s·conj(phase)·a_q,
                //                               s·phase·a_p + c·a_q]
                let sp = phase.scale(s);
                let spc = phase.conj().scale(s);
                for i in 0..m {
                    let ap = w.cols[p][i];
                    let aq = w.cols[q][i];
                    w.cols[p][i] = ap.scale(c).sub(spc.mul(aq));
                    w.cols[q][i] = sp.mul(ap).add(aq.scale(c));
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = vp.scale(c).sub(spc.mul(vq));
                    v[q][i] = sp.mul(vp).add(vq.scale(c));
                }
            }
        }
        if off < eps {
            break;
        }
    }

    // Extract singular values and sort descending.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = w.cols[j]
                .iter()
                .map(|z| z.re * z.re + z.im * z.im)
                .sum::<f64>()
                .sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = CMat::zeros(m, n);
    let mut vh = CMat::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (rank, &(sigma, j)) in sv.iter().enumerate() {
        s_out.push(sigma as f32);
        if sigma > 0.0 {
            for i in 0..m {
                let z = w.cols[j][i].scale(1.0 / sigma);
                u.re[i * n + rank] = z.re as f32;
                u.im[i * n + rank] = z.im as f32;
            }
        }
        // Row `rank` of V^H is conj of column j of V.
        for i in 0..n {
            let z = v[j][i];
            vh.re[rank * n + i] = z.re as f32;
            vh.im[rank * n + i] = -z.im as f32;
        }
    }
    SvdC {
        u,
        s: s_out,
        vh,
    }
}

/// One-sided Jacobi SVD of a real matrix (thin wrapper over the complex
/// path; the imaginary plane stays exactly zero through real rotations,
/// but we run the dedicated real loop for speed).
pub fn svd_real(a: &Mat) -> SvdR {
    let c = svd_complex(&a.to_cmat());
    SvdR {
        u: c.u.real(),
        s: c.s,
        vt: c.vh.real(),
    }
}

/// Best rank-k approximation (Eckart–Young) of a complex matrix.
pub fn low_rank_approx(a: &CMat, k: usize) -> CMat {
    let SvdC { u, s, vh } = svd_complex(a);
    let r = k.min(s.len());
    // U_k · diag(s_k) · Vh_k
    let mut uk = CMat::zeros(a.rows, r);
    for i in 0..a.rows {
        for j in 0..r {
            let src = i * s.len() + j;
            uk.re[i * r + j] = u.re[src] * s[j];
            uk.im[i * r + j] = u.im[src] * s[j];
        }
    }
    let mut vhk = CMat::zeros(r, a.cols);
    for j in 0..r {
        for c in 0..a.cols {
            let src = j * a.cols + c;
            vhk.re[j * a.cols + c] = vh.re[src];
            vhk.im[j * a.cols + c] = vh.im[src];
        }
    }
    uk.matmul(&vhk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::complex::Cpx;
    use crate::util::rng::Rng;

    fn reconstruct(svd: &SvdC, m: usize, n: usize) -> CMat {
        let r = svd.s.len();
        let mut us = CMat::zeros(m, r);
        for i in 0..m {
            for j in 0..r {
                us.re[i * r + j] = svd.u.re[i * r + j] * svd.s[j];
                us.im[i * r + j] = svd.u.im[i * r + j] * svd.s[j];
            }
        }
        let _ = n;
        us.matmul(&svd.vh)
    }

    #[test]
    fn svd_reconstructs_random_complex() {
        let mut rng = Rng::new(7);
        let a = CMat::from_fn(12, 8, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let svd = svd_complex(&a);
        let b = reconstruct(&svd, 12, 8);
        assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
        // Singular values descending and nonnegative.
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn svd_wide_matrix() {
        let mut rng = Rng::new(8);
        let a = CMat::from_fn(5, 9, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let svd = svd_complex(&a);
        let b = reconstruct(&svd, 5, 9);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Rng::new(9);
        let a = CMat::from_fn(10, 6, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let svd = svd_complex(&a);
        let gram = svd.u.conj_transpose().matmul(&svd.u);
        let eye = CMat::eye(6);
        assert!(gram.max_abs_diff(&eye) < 1e-4, "gram diff {}", gram.max_abs_diff(&eye));
    }

    #[test]
    fn low_rank_exact_for_low_rank_input() {
        // Build an exactly rank-2 matrix and check rank-2 approx recovers it.
        let mut rng = Rng::new(10);
        let u = CMat::from_fn(8, 2, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let v = CMat::from_fn(2, 8, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let a = u.matmul(&v);
        let approx = low_rank_approx(&a, 2);
        assert!(a.max_abs_diff(&approx) < 1e-3, "{}", a.max_abs_diff(&approx));
    }

    #[test]
    fn eckart_young_improves_with_rank() {
        let mut rng = Rng::new(11);
        let a = CMat::from_fn(16, 16, |_, _| {
            Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
        });
        let e1 = a.sub(&low_rank_approx(&a, 1)).frobenius_norm();
        let e4 = a.sub(&low_rank_approx(&a, 4)).frobenius_norm();
        let e16 = a.sub(&low_rank_approx(&a, 16)).frobenius_norm();
        assert!(e1 > e4);
        assert!(e4 > e16);
        assert!(e16 < 1e-3);
    }

    #[test]
    fn real_svd_diag() {
        let a = Mat::from_rows(vec![
            vec![3.0, 0.0],
            vec![0.0, -2.0],
        ]);
        let svd = svd_real(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
    }
}
