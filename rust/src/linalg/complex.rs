//! Complex arithmetic over `f32` (no `num-complex` in the vendored set).
//!
//! Layout note: bulk data (matrices, butterfly twiddles) is stored in
//! *planar* real/imag arrays to match the `[2, ...]` real-pair layout used
//! by the JAX model and the PJRT literals; `Cpx` is the scalar type used
//! inside inner loops and tests.

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex scalar with `f32` components.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cpx {
    pub re: f32,
    pub im: f32,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };
    pub const ONE: Cpx = Cpx { re: 1.0, im: 0.0 };
    pub const I: Cpx = Cpx { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        Cpx { re, im }
    }

    #[inline]
    pub fn real(re: f32) -> Self {
        Cpx { re, im: 0.0 }
    }

    /// e^{iθ} = cosθ + i sinθ. Computed in f64 for accuracy at large N
    /// (twiddle factors for N=1024 need precise angles).
    #[inline]
    pub fn cis(theta: f64) -> Self {
        Cpx {
            re: theta.cos() as f32,
            im: theta.sin() as f32,
        }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Cpx {
            re: self.re,
            im: -self.im,
        }
    }

    #[inline]
    pub fn abs2(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f32 {
        self.abs2().sqrt()
    }

    /// Multiplicative inverse.
    #[inline]
    pub fn inv(self) -> Self {
        let d = self.abs2();
        Cpx {
            re: self.re / d,
            im: -self.im / d,
        }
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: f32) -> Self {
        Cpx {
            re: self.re * s,
            im: self.im * s,
        }
    }

    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl Add for Cpx {
    type Output = Cpx;
    #[inline]
    fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for Cpx {
    type Output = Cpx;
    #[inline]
    fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for Cpx {
    type Output = Cpx;
    #[inline]
    fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for Cpx {
    type Output = Cpx;
    #[inline]
    fn div(self, o: Cpx) -> Cpx {
        self * o.inv()
    }
}

impl Neg for Cpx {
    type Output = Cpx;
    #[inline]
    fn neg(self) -> Cpx {
        Cpx::new(-self.re, -self.im)
    }
}

impl AddAssign for Cpx {
    #[inline]
    fn add_assign(&mut self, o: Cpx) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for Cpx {
    #[inline]
    fn sub_assign(&mut self, o: Cpx) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for Cpx {
    #[inline]
    fn mul_assign(&mut self, o: Cpx) {
        *self = *self * o;
    }
}

impl Mul<f32> for Cpx {
    type Output = Cpx;
    #[inline]
    fn mul(self, s: f32) -> Cpx {
        self.scale(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: Cpx, b: Cpx, tol: f32) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn field_axioms_spotcheck() {
        let a = Cpx::new(1.5, -2.0);
        let b = Cpx::new(-0.25, 3.0);
        let c = Cpx::new(4.0, 0.5);
        assert!(close(a * (b + c), a * b + a * c, 1e-5));
        assert!(close((a * b) * c, a * (b * c), 1e-4));
        assert!(close(a + (-a), Cpx::ZERO, 1e-6));
        assert!(close(a * a.inv(), Cpx::ONE, 1e-6));
        assert!(close(a / b * b, a, 1e-5));
    }

    #[test]
    fn i_squared_is_minus_one() {
        assert!(close(Cpx::I * Cpx::I, -Cpx::ONE, 1e-7));
    }

    #[test]
    fn cis_unit_circle() {
        for k in 0..16 {
            let th = 2.0 * std::f64::consts::PI * k as f64 / 16.0;
            let z = Cpx::cis(th);
            assert!((z.abs() - 1.0).abs() < 1e-6);
        }
        // 8th roots of unity multiply to expected values.
        let w = Cpx::cis(2.0 * std::f64::consts::PI / 8.0);
        let mut acc = Cpx::ONE;
        for _ in 0..8 {
            acc *= w;
        }
        assert!(close(acc, Cpx::ONE, 1e-5));
    }

    #[test]
    fn conj_properties() {
        let a = Cpx::new(2.0, -3.0);
        let b = Cpx::new(-1.0, 0.5);
        assert!(close((a * b).conj(), a.conj() * b.conj(), 1e-5));
        assert!((a * a.conj()).im.abs() < 1e-6);
        assert!(((a * a.conj()).re - a.abs2()).abs() < 1e-5);
    }
}
