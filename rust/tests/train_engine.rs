//! Grad-parity and determinism suite for the workspace training engine.
//!
//! Contract under test (see `butterfly::workspace`):
//! - the workspace path and the per-call-allocating path run the same
//!   kernels over the same chunking, so they agree **bit-for-bit**;
//! - the chunk-parallel driver at `T = 1` is the serial path exactly;
//! - at `T ∈ {2, 8}` only the floating-point regrouping of chunk sums
//!   changes, so gradients agree to ≤ 1e-6 and results for a fixed `T`
//!   are bit-reproducible;
//! - the Hyperband scheduler built on top of it is deterministic across
//!   runs *and* worker counts (per-trial work and rung ranking no longer
//!   depend on worker finish order).

use butterfly::butterfly::module::{BpModule, BpStack, FactorizeLoss};
use butterfly::butterfly::params::{BpParams, Field, InitScheme, PermTying, TwiddleTying};
use butterfly::butterfly::workspace::{ParallelTrainer, TrainWorkspace};
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;

fn rand_stack(n: usize, depth: usize, field: Field, tying: TwiddleTying, seed: u64) -> BpStack {
    let mut rng = Rng::new(seed);
    let mods = (0..depth)
        .map(|_| {
            let mut p = BpParams::init(n, field, tying, PermTying::Untied, InitScheme::OrthogonalLike, &mut rng);
            for k in 0..p.levels {
                for g in 0..3 {
                    p.set_logit(k, g, rng.normal_f32(0.0, 1.0));
                }
            }
            BpModule::new(p)
        })
        .collect();
    BpStack::new(mods)
}

/// Every (field × twiddle-tying × chunk) cell: workspace serial path and
/// 1-thread parallel path must match the allocating path bit-for-bit.
#[test]
fn workspace_paths_match_allocating_path_bitwise() {
    let n = 16;
    for field in [Field::Real, Field::Complex] {
        for tying in [TwiddleTying::Factor, TwiddleTying::Block] {
            let seed = 100 + field as u64 * 10 + tying as u64;
            let stack = rand_stack(n, 2, field, tying, seed);
            let target = rand_stack(n, 2, Field::Complex, TwiddleTying::Factor, seed + 1).to_matrix();
            for chunk in [3usize, 7, n] {
                let mut loss_fn = FactorizeLoss::new(target.clone());
                loss_fn.chunk = chunk;
                let ctx = format!("{field:?}/{tying:?}/chunk {chunk}");

                let mut g_ref = stack.zero_grad();
                let l_ref = loss_fn.loss_and_grad(&stack, &mut g_ref);

                let mut ws = TrainWorkspace::for_stack(&stack);
                let mut g_ws = stack.zero_grad();
                let l_ws = loss_fn.loss_and_grad_ws(&stack, &mut g_ws, &mut ws);
                assert_eq!(l_ref.to_bits(), l_ws.to_bits(), "loss diverged ({ctx})");
                for (a, b) in g_ref.iter().flatten().zip(g_ws.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "serial ws grad diverged ({ctx})");
                }

                let mut pool = ParallelTrainer::new(n, 1);
                let mut g_p1 = stack.zero_grad();
                let l_p1 = loss_fn.loss_and_grad_parallel(&stack, &mut g_p1, &mut pool);
                assert_eq!(l_ref.to_bits(), l_p1.to_bits(), "1-thread loss diverged ({ctx})");
                for (a, b) in g_ref.iter().flatten().zip(g_p1.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "1-thread grad diverged ({ctx})");
                }
            }
        }
    }
}

/// Thread counts 2 and 8 regroup chunk sums only: ≤ 1e-6 from serial,
/// and bit-reproducible for a fixed thread count.
#[test]
fn parallel_grads_match_serial_across_thread_counts() {
    let n = 16;
    for field in [Field::Real, Field::Complex] {
        for tying in [TwiddleTying::Factor, TwiddleTying::Block] {
            let seed = 200 + field as u64 * 10 + tying as u64;
            let stack = rand_stack(n, 2, field, tying, seed);
            let target = rand_stack(n, 2, Field::Complex, TwiddleTying::Factor, seed + 1).to_matrix();
            for chunk in [3usize, 7, n] {
                let mut loss_fn = FactorizeLoss::new(target.clone());
                loss_fn.chunk = chunk;
                let ctx = format!("{field:?}/{tying:?}/chunk {chunk}");

                let mut ws = TrainWorkspace::for_stack(&stack);
                let mut g_ser = stack.zero_grad();
                let l_ser = loss_fn.loss_and_grad_ws(&stack, &mut g_ser, &mut ws);

                for threads in [2usize, 8] {
                    let mut pool = ParallelTrainer::new(n, threads);
                    let mut g_par = stack.zero_grad();
                    let l_par = loss_fn.loss_and_grad_parallel(&stack, &mut g_par, &mut pool);
                    assert!(
                        (l_par - l_ser).abs() <= 1e-9 * (1.0 + l_ser.abs()),
                        "T={threads} loss {l_par} vs {l_ser} ({ctx})"
                    );
                    for (a, b) in g_par.iter().flatten().zip(g_ser.iter().flatten()) {
                        assert!(
                            (a - b).abs() <= 1e-6 * (1.0 + b.abs()),
                            "T={threads} grad {a} vs {b} ({ctx})"
                        );
                    }
                    // rerun with the same pool: bit-identical
                    let mut g_rep = stack.zero_grad();
                    let l_rep = loss_fn.loss_and_grad_parallel(&stack, &mut g_rep, &mut pool);
                    assert_eq!(l_par.to_bits(), l_rep.to_bits(), "T={threads} rerun loss ({ctx})");
                    for (a, b) in g_par.iter().flatten().zip(g_rep.iter().flatten()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "T={threads} rerun grad ({ctx})");
                    }
                }
            }
        }
    }
}

/// `resumable_equals_straight_run`, scheduler edition: with a target the
/// step budget cannot reach, the whole Hyperband search — sampled
/// configs, per-trial training, rung ranking, survivor selection, final
/// θ — must be identical run-to-run and across worker counts.
#[test]
fn scheduler_is_deterministic_across_runs_and_worker_counts() {
    let mk_job = || {
        let mut job = FactorizeJob::paper(TransformKind::Hadamard, 8, 5, 10_000);
        job.target_rmse = 1e-12; // unreachable: early stop never fires
        job
    };
    let mk_cfg =
        |workers| SchedulerConfig { workers, max_resource: 9, eta: 3, step_quantum: 5, seed: 21 };
    let base = run_job(&mk_job(), &mk_cfg(1), &Metrics::new(), &Registry::new());
    for workers in [1usize, 4] {
        let res = run_job(&mk_job(), &mk_cfg(workers), &Metrics::new(), &Registry::new());
        assert_eq!(res.best_rmse.to_bits(), base.best_rmse.to_bits(), "workers = {workers}");
        assert_eq!(res.best_theta, base.best_theta, "workers = {workers}");
        assert_eq!(res.total_steps, base.total_steps, "workers = {workers}");
        assert_eq!(res.best_config, base.best_config, "workers = {workers}");
        assert_eq!(res.trials_run, base.trials_run, "workers = {workers}");
    }
}

/// End-to-end stale-RMSE regression: the parameters a job hands to
/// serving must reproduce the RMSE the job reported for them.
#[test]
fn job_best_theta_reproduces_reported_rmse() {
    let job = FactorizeJob::paper(TransformKind::Dft, 8, 42, 2000);
    let cfg = SchedulerConfig { workers: 2, max_resource: 9, eta: 3, step_quantum: 25, seed: 11 };
    let res = run_job(&job, &cfg, &Metrics::new(), &Registry::new());
    let stack = butterfly::runtime::engine::unpack_stack(job.n, job.depth, &res.best_theta);
    let served = FactorizeLoss::new(job.target.clone()).rmse(&stack);
    assert!(
        (res.best_rmse - served).abs() <= 1e-7 * (1.0 + served),
        "job reported rmse {} but its theta reconstructs to {}",
        res.best_rmse,
        served
    );
}
