//! Soak tests for the shared-queue [`ServicePool`]: correctness under
//! many concurrent clients, work-conservation with a deliberately slow
//! lane (a deep backlog of heavyweight requests), backpressure
//! accounting, and shutdown-while-pending draining every accepted
//! request exactly once.
//!
//! These run in CI under `--release` as well — the races the shared
//! queue must survive hide in debug-build timing.
//!
//! [`ServicePool`]: butterfly::serving::ServicePool

use butterfly::butterfly::closed_form::dft_stack;
use butterfly::linalg::complex::Cpx;
use butterfly::serving::{BatcherConfig, ServicePool};
use butterfly::transforms::op::stack_op;
use butterfly::transforms::matrices::dft_matrix;
use butterfly::util::rng::Rng;
use std::time::Duration;

fn parallel_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Dense reference for one complex input.
fn dense_dft(n: usize, re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let f = dft_matrix(n);
    let x: Vec<Cpx> = (0..n).map(|i| Cpx::new(re[i], im[i])).collect();
    let y = f.matvec(&x);
    (y.iter().map(|c| c.re).collect(), y.iter().map(|c| c.im).collect())
}

#[test]
fn soak_every_reply_matches_dense_reference() {
    let n = 64;
    let pool = ServicePool::spawn(
        "dft",
        stack_op("dft", &dft_stack(n)),
        4,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300), queue_cap: 8192 },
    );
    let clients = 12usize;
    let per_client = 40usize;
    let threads: Vec<_> = (0..clients)
        .map(|t| {
            let h = pool.handle();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 + t as u64);
                // pipeline the whole load first (builds a real backlog),
                // then redeem and verify every ticket
                let mut inflight = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let mut re = vec![0.0f32; n];
                    let mut im = vec![0.0f32; n];
                    rng.fill_normal(&mut re, 0.0, 1.0);
                    rng.fill_normal(&mut im, 0.0, 1.0);
                    let ticket = h.submit(re.clone(), im.clone()).expect("submit");
                    inflight.push((re, im, ticket));
                }
                for (re, im, ticket) in inflight {
                    let (gr, gi) = ticket.wait().expect("reply");
                    let (wr, wi) = dense_dft(n, &re, &im);
                    for i in 0..n {
                        assert!((gr[i] - wr[i]).abs() < 1e-3, "re[{i}]: {} vs {}", gr[i], wr[i]);
                        assert!((gi[i] - wi[i]).abs() < 1e-3, "im[{i}]: {} vs {}", gi[i], wi[i]);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    // every answered batch bumped its worker's load counter before the
    // reply was sent, so with all clients joined this snapshot is exact
    let loads = pool.worker_loads();
    let active = loads.iter().filter(|&&b| b > 0).count();
    // all clients joined ⇒ the pool is quiescent and the live gauges
    // (which admission control budgets against) are back to zero
    assert_eq!(pool.in_flight(), 0, "quiescent pool must report zero in-flight");
    assert_eq!(pool.queue_depth(), 0, "quiescent pool must report an empty queue");
    let stats = pool.shutdown();
    assert_eq!(stats.served, clients * per_client);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.bad_request, 0);
    assert_eq!(stats.in_flight, 0);
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(
        stats.batch_hist.iter().sum::<usize>(),
        stats.batches,
        "every drained batch lands in exactly one histogram bucket"
    );
    // on a single-core machine the OS may legitimately let one worker
    // drain everything; with real parallelism the shared queue must not
    if parallel_cores() >= 2 {
        assert!(
            active >= 2,
            "a {clients}-client pipelined soak must engage >1 worker of the shared queue, got loads {loads:?}"
        );
    }
}

#[test]
fn slow_lane_backlog_is_drained_by_idle_siblings() {
    // The head-of-line regression scenario: a deep backlog of heavyweight
    // requests (n = 1024, max_batch = 1 ⇒ every request is its own slow
    // batch). Under the old one-queue-per-replica router, the requests
    // round-robined onto the flooded replica waited behind the whole
    // backlog while other replicas idled. The shared queue must instead
    // spread the backlog over every worker (work conservation) and keep
    // serving probe clients correctly throughout.
    let n = 1024;
    let pool = ServicePool::spawn(
        "dft",
        stack_op("dft", &dft_stack(n)),
        4,
        BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(0), queue_cap: 4096 },
    );

    // the slow lane: one client floods 96 pipelined heavyweight requests
    let flood = {
        let h = pool.handle();
        std::thread::spawn(move || {
            let mut rng = Rng::new(7);
            let tickets: Vec<_> = (0..96)
                .map(|_| {
                    let mut re = vec![0.0f32; n];
                    rng.fill_normal(&mut re, 0.0, 1.0);
                    h.submit(re, vec![0.0; n]).expect("flood submit")
                })
                .collect();
            let mut got = 0usize;
            for t in tickets {
                let (re, im) = t.wait().expect("flood reply");
                assert!(re.iter().chain(im.iter()).all(|v| v.is_finite()));
                got += 1;
            }
            got
        })
    };

    // probe clients make synchronous calls while the backlog is deep;
    // each answer is checked against the dense reference
    let probes: Vec<_> = (0..3)
        .map(|t| {
            let h = pool.handle();
            std::thread::spawn(move || {
                let mut rng = Rng::new(40 + t as u64);
                for _ in 0..6 {
                    let mut re = vec![0.0f32; n];
                    rng.fill_normal(&mut re, 0.0, 1.0);
                    let im = vec![0.0f32; n];
                    let (gr, gi) = h.call(re.clone(), im.clone()).expect("probe call");
                    let (wr, wi) = dense_dft(n, &re, &im);
                    for i in 0..n {
                        assert!((gr[i] - wr[i]).abs() < 1e-2, "probe re[{i}]");
                        assert!((gi[i] - wi[i]).abs() < 1e-2, "probe im[{i}]");
                    }
                }
            })
        })
        .collect();

    assert_eq!(flood.join().unwrap(), 96, "every flood request answered exactly once");
    for p in probes {
        p.join().unwrap();
    }
    let loads = pool.worker_loads();
    let active = loads.iter().filter(|&&b| b > 0).count();
    let stats = pool.shutdown();
    assert_eq!(stats.served, 96 + 3 * 6);
    assert_eq!(stats.rejected, 0);
    if parallel_cores() >= 2 {
        assert!(
            active >= 2,
            "a 96-deep slow lane must be drained by multiple workers, not serialize on one: {loads:?}"
        );
    }
}

#[test]
fn backpressure_full_is_counted_and_never_deadlocks() {
    let n = 256;
    let pool = ServicePool::spawn(
        "dft",
        stack_op("dft", &dft_stack(n)),
        2,
        BatcherConfig { max_batch: 2, max_wait: Duration::from_micros(50), queue_cap: 4 },
    );
    let producers: Vec<_> = (0..8)
        .map(|t| {
            let h = pool.handle();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                let mut ok = 0usize;
                let mut rejected = 0usize;
                for _ in 0..40 {
                    let mut x = vec![0.0f32; n];
                    rng.fill_normal(&mut x, 0.0, 1.0);
                    match h.submit(x, vec![0.0; n]) {
                        Ok(ticket) => {
                            ticket.wait().expect("accepted request must be answered");
                            ok += 1;
                        }
                        Err(_) => rejected += 1,
                    }
                }
                (ok, rejected)
            })
        })
        .collect();
    let mut total_ok = 0usize;
    let mut total_rej = 0usize;
    for p in producers {
        let (ok, rej) = p.join().unwrap();
        total_ok += ok;
        total_rej += rej;
    }
    let stats = pool.shutdown();
    assert_eq!(total_ok + total_rej, 320);
    assert_eq!(stats.served, total_ok, "served must equal accepted");
    assert_eq!(stats.rejected, total_rej, "every Full must be counted");
    assert!(total_ok > 0);
}

#[test]
fn shutdown_while_pending_drains_every_accepted_request_exactly_once() {
    let n = 256;
    let pool = ServicePool::spawn(
        "dft",
        stack_op("dft", &dft_stack(n)),
        4,
        // a huge window: without shutdown cutting it short, the backlog
        // would sit in the queue for seconds
        BatcherConfig { max_batch: 4, max_wait: Duration::from_secs(5), queue_cap: 8192 },
    );
    let h = pool.handle();
    let mut rng = Rng::new(9);
    let total = 200usize;
    let tickets: Vec<_> = (0..total)
        .map(|_| {
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x, 0.0, 1.0);
            h.submit(x, vec![0.0; n]).expect("submit")
        })
        .collect();
    // close with (almost) everything still pending: workers must drain
    // the whole backlog before joining
    let stats = pool.shutdown();
    assert_eq!(stats.served, total, "shutdown must drain every accepted request");
    assert_eq!(stats.in_flight, 0, "drained pool must report zero in-flight");
    assert_eq!(stats.queue_depth, 0);
    for (i, t) in tickets.into_iter().enumerate() {
        let (re, im) = t.wait().unwrap_or_else(|e| panic!("ticket {i} dropped: {e}"));
        assert!(re.iter().chain(im.iter()).all(|v| v.is_finite()));
    }
    // post-shutdown, new requests are refused, not queued forever
    assert!(h.submit(vec![0.0; n], vec![0.0; n]).is_err());
}
