//! End-to-end pipeline tests for the K-matrix (BB*) module: artifact
//! round-trip through real serialized bytes, serving through the
//! Router, training-tier bit-reproducibility, and the coordinator
//! recovery scenarios (circulant-with-unknown-permutation and
//! sparse-dictionary targets) against the matched-budget baselines.

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline};
use butterfly::butterfly::kmatrix::{kmatrix_theta_len, KMatrix};
use butterfly::butterfly::{identify, FactorizeLoss, ParallelTrainer};
use butterfly::linalg::complex::Cpx;
use butterfly::linalg::dense::CMat;
use butterfly::nn::butterfly_layer::ButterflyLayer;
use butterfly::butterfly::params::{log2_exact, Field};
use butterfly::butterfly::permutation::{hard_perm_table, invert_table};
use butterfly::runtime::artifacts::LayerArtifact;
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::fuse::FuseSpec;
use butterfly::transforms::matrices;
use butterfly::transforms::op::{stack_op, stack_op_fused, OpWorkspace};
use butterfly::util::json;
use butterfly::util::rng::Rng;

/// Column-major planar apply of `op` to `batch` random real vectors.
fn apply_cols(op: &dyn butterfly::transforms::op::LinearOp, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let n = op.n();
    let mut re = vec![0.0f32; n * batch];
    Rng::new(seed).fill_normal(&mut re, 0.0, 1.0);
    let mut im = vec![0.0f32; n * batch];
    let mut ws = OpWorkspace::new();
    op.apply_batch(&mut re, &mut im, batch, &mut ws);
    (re, im)
}

#[test]
fn kmatrix_artifact_roundtrips_bitwise_through_serialized_json() {
    let n = 32;
    let mut rng = Rng::new(8);
    let layer = ButterflyLayer::kmatrix(n, Field::Real, &mut rng);
    let art = layer.export_artifact("compress-hidden");
    assert_eq!(art.kind, "kmatrix");
    assert_eq!(art.theta.len(), kmatrix_theta_len(n));
    // through the REAL serialized form — the exact bytes --save writes
    let text = art.to_json().to_string_pretty();
    let back = LayerArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
    for (a, b) in art.theta.iter().zip(&back.theta) {
        assert_eq!(a.to_bits(), b.to_bits(), "theta must round-trip bitwise");
    }
    let direct = layer.export_op("compress-hidden");
    let rebuilt = back.to_op().unwrap();
    for batch in [1usize, 3, 64] {
        let (dr, di) = apply_cols(direct.as_ref(), batch, 1000 + batch as u64);
        let (rr, ri) = apply_cols(rebuilt.as_ref(), batch, 1000 + batch as u64);
        for (a, b) in dr.iter().zip(&rr) {
            assert_eq!(a.to_bits(), b.to_bits(), "batch {batch}: re plane diverged");
        }
        for (a, b) in di.iter().zip(&ri) {
            assert_eq!(a.to_bits(), b.to_bits(), "batch {batch}: im plane diverged");
        }
    }
}

#[test]
fn kmatrix_artifact_fused_rebuild_matches_direct_fuse_bitwise() {
    let n = 64;
    let mut rng = Rng::new(9);
    let k = KMatrix::init(n, Field::Real, &mut rng);
    let layer = ButterflyLayer::from_stack(k.stack().clone());
    let art = layer.export_artifact("fused-km");
    let spec = FuseSpec::parse("balanced:2").unwrap();
    let direct = stack_op_fused("fused-km", k.stack(), &spec);
    let rebuilt = art.to_op_with(Some(&spec)).unwrap();
    let (dr, _) = apply_cols(direct.as_ref(), 3, 77);
    let (rr, _) = apply_cols(rebuilt.as_ref(), 3, 77);
    for (a, b) in dr.iter().zip(&rr) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // and fused vs unfused stay numerically together
    let unfused = stack_op("fused-km", k.stack());
    let (ur, _) = apply_cols(unfused.as_ref(), 3, 77);
    for (a, b) in ur.iter().zip(&rr) {
        assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

#[test]
fn kmatrix_serves_through_the_router() {
    let n = 64;
    let mut rng = Rng::new(10);
    let k = KMatrix::init(n, Field::Real, &mut rng);
    let op = stack_op("kmatrix", k.stack());
    assert!(!op.is_complex(), "real-field K-matrix must harden to the real path");
    let reference = stack_op("kmatrix", k.stack());
    let mut router = Router::new();
    router.install("kmatrix", op, 2, BatcherConfig::default());
    let handle = router.handle("kmatrix").unwrap();
    let mut ws = OpWorkspace::new();
    for i in 0..40u64 {
        let mut x = vec![0.0f32; n];
        Rng::new(500 + i).fill_normal(&mut x, 0.0, 1.0);
        let served = handle.call_real(x.clone()).expect("serve");
        let mut re = x;
        let mut im = vec![0.0f32; n];
        reference.apply_batch(&mut re, &mut im, 1, &mut ws);
        for (a, b) in served.iter().zip(&re) {
            assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()), "req {i}: {a} vs {b}");
        }
    }
    let stats = router.shutdown();
    assert_eq!(stats["kmatrix"].served, 40);
}

#[test]
fn kmatrix_gradients_are_bit_identical_across_thread_counts() {
    // the ParallelTrainer reproducibility contract extends to Block-tied
    // stacks: same loss, bitwise-same gradients for any worker count
    let n = 16;
    let mut rng = Rng::new(11);
    let stack = KMatrix::init(n, Field::Complex, &mut rng).into_stack();
    let target = matrices::dft_matrix(n);
    let loss = FactorizeLoss::new(target);
    let mut results: Vec<(f64, Vec<Vec<f32>>)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut pool = ParallelTrainer::new(n, threads);
        let mut grad = stack.zero_grad();
        let l = loss.loss_and_grad_parallel(&stack, &mut grad, &mut pool);
        results.push((l, grad));
    }
    for (l, g) in &results[1..] {
        assert_eq!(l.to_bits(), results[0].0.to_bits(), "loss diverged across thread counts");
        assert_eq!(g, &results[0].1, "gradients diverged across thread counts");
    }
}

#[test]
fn circulant_with_unknown_permutation_beats_matched_budget_baselines() {
    // the coordinator scenario: target = C · P_bitrev, a circulant whose
    // input ordering was scrambled. Identification must recover it
    // EXACTLY (zero optimizer steps) while low-rank and sparse baselines
    // at the same parameter budget are stuck far away.
    let n = 32;
    let mut rng = Rng::new(12);
    let mut h = vec![0.0f32; n];
    rng.fill_normal(&mut h, 0.0, (1.0 / n as f64).sqrt() as f32);
    let c = matrices::circulant_matrix(&h).to_cmat();
    let t = hard_perm_table(n, &vec![[true, false, false]; log2_exact(n)]);
    let inv = invert_table(&t);
    // (C·P)[i, j] = C[i, inv(t)[j]]
    let target = CMat::from_fn(n, n, |i, j| c.at(i, inv[j]));

    let got = identify(&target);
    assert!(got.exact, "relative {} via {}", got.relative, got.method);
    assert_eq!(got.method, "kmatrix-circulant/bit-reversal");

    let budget = butterfly_budget(n, 2);
    assert!(budget < n * n, "scenario only meaningful under the dense budget");
    let lr = lowrank_baseline(&target, budget);
    let sp = sparse_baseline(&target, budget);
    for (name, fit) in [("low-rank", &lr), ("sparse", &sp)] {
        assert!(
            fit.rmse > 1e-3,
            "{name} baseline unexpectedly fit a permuted circulant: rmse {}",
            fit.rmse
        );
        assert!(
            fit.rmse > 50.0 * got.rmse.max(1e-12),
            "{name}: {} not clearly worse than identified {}",
            fit.rmse,
            got.rmse
        );
    }
}

#[test]
fn sparse_dictionary_target_is_not_a_kmatrix_win() {
    // honesty check the other way: a random sparse dictionary inside the
    // sparse baseline's budget is representable exactly by the sparse
    // baseline but NOT by butterfly identification — which must say so
    // (not exact) while still returning a finite warm start.
    let n = 32;
    let budget = butterfly_budget(n, 2);
    let nnz = budget / 4;
    let mut rng = Rng::new(13);
    let mut target = CMat::zeros(n, n);
    for _ in 0..nnz {
        let i = rng.below(n);
        let j = rng.below(n);
        target.set(i, j, Cpx::new(rng.normal_f32(0.0, 1.0), 0.0));
    }
    let sp = sparse_baseline(&target, budget);
    assert!(sp.rmse < 1e-9, "sparse baseline should capture its own regime: rmse {}", sp.rmse);
    let got = identify(&target);
    assert!(!got.exact, "a random sparse dictionary must not identify as butterfly");
    assert!(got.rmse.is_finite());
    assert!(
        got.relative < 1.0,
        "the hierarchical projection still captures some mass, got {}",
        got.relative
    );
}
