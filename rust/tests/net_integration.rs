//! End-to-end loopback tests of the network serving tier: real sockets,
//! real threads, the same `butterfly serve --listen` wiring the CLI
//! uses. The contracts pinned here are the ISSUE's acceptance criteria:
//! network answers bitwise identical to in-process `Router::call`, a
//! ≥32-connection keep-alive soak with zero lost or duplicated replies
//! and `/metrics` counters that exactly match what the load generator
//! sent, overload shedding with 429 (never a hang), graceful drain
//! completing every accepted request, and `/admin/reload` hot-swapping
//! a route mid-traffic without invalid responses.

use butterfly::net::http;
use butterfly::net::loadgen::{self, LoadgenConfig};
use butterfly::net::{Server, ServerConfig};
use butterfly::runtime::artifacts::LayerArtifact;
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::op::{plan_with_rng, LinearOp, OpWorkspace};
use butterfly::transforms::spec::TransformKind;
use butterfly::util::json::{self, obj, Json};
use butterfly::util::rng::Rng;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

/// The pinned route op: fast DCT at `n` (real, deterministic — the rng
/// is unused by the DCT plan, so two builds are the same op).
fn dct_op(n: usize) -> Arc<dyn LinearOp> {
    plan_with_rng(TransformKind::Dct, n, &mut Rng::new(11))
}

fn start_server(n: usize, workers: usize, budget: usize) -> Server {
    let mut router = Router::new();
    router.install("dct", dct_op(n), workers, BatcherConfig::default());
    Server::start(
        router,
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            max_connections: 64,
            inflight_budget: budget,
            adaptive_cap: Some(Duration::from_micros(500)),
            fuse: None,
        },
    )
    .expect("bind loopback")
}

/// One request/response round trip on a fresh connection.
fn roundtrip(addr: &str, raw: &[u8]) -> (u16, Vec<u8>) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).ok();
    let read_half = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    writer.write_all(raw).unwrap();
    writer.flush().unwrap();
    http::read_response(&mut reader).expect("response")
}

fn post_json(addr: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let raw = format!(
        "POST {path} HTTP/1.1\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    );
    roundtrip(addr, raw.as_bytes())
}

fn get(addr: &str, path: &str) -> (u16, Vec<u8>) {
    roundtrip(addr, format!("GET {path} HTTP/1.1\r\n\r\n").as_bytes())
}

/// Pull one counter value out of a Prometheus text page.
fn metric_value(page: &str, name: &str) -> Option<f64> {
    page.lines().find_map(|l| {
        let (metric, value) = l.split_once(' ')?;
        (metric == name).then(|| value.parse().ok())?
    })
}

fn parse_plane_f32(doc: &Json, key: &str) -> Vec<Vec<f32>> {
    doc.get(key)
        .and_then(|p| p.as_arr())
        .expect("plane")
        .iter()
        .map(|row| {
            row.as_arr().expect("row").iter().map(|v| v.as_f64().unwrap() as f32).collect()
        })
        .collect()
}

#[test]
fn http_apply_is_bitwise_identical_to_in_process_call() {
    let n = 64usize;
    let server = start_server(n, 2, 512);
    let addr = server.local_addr().to_string();

    // twin in-process route over the identical op
    let mut local = Router::new();
    local.install("dct", dct_op(n), 1, BatcherConfig::default());

    let mut rng = Rng::new(0xB17);
    let rows: Vec<Vec<f32>> = (0..3)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let body = obj(vec![
        ("route", "dct".into()),
        (
            "re",
            Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(f64::from(x))).collect()))
                    .collect(),
            ),
        ),
    ])
    .to_string_compact();
    let (status, resp) = post_json(&addr, "/v1/apply", &body);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    let doc = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
    let got = parse_plane_f32(&doc, "re");
    assert_eq!(got.len(), rows.len());
    for (i, row) in rows.iter().enumerate() {
        let want = local.call_real("dct", row.clone()).unwrap();
        let same = want.iter().zip(&got[i]).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "row {i}: network answer differs from in-process Router::call");
    }

    server.shutdown_handle().drain();
    server.join();
    local.shutdown();
}

#[test]
fn soak_32_keep_alive_connections_loses_nothing_and_metrics_match() {
    let n = 32usize;
    let server = start_server(n, 4, 1 << 20);
    let addr = server.local_addr().to_string();

    let cfg = LoadgenConfig {
        addr: addr.clone(),
        route: "dct".into(),
        n,
        complex: false,
        connections: 32,
        requests_per_conn: 8,
        batch: 4,
        seed: 5,
    };
    // run() errors on any lost, duplicated, or cross-wired reply (tag
    // echo), any short batch, and any non-(200|429) status
    let report = loadgen::run(&cfg).expect("soak must lose nothing");
    assert_eq!(report.requests, 32 * 8);
    assert_eq!(report.ok, report.requests, "high budget: nothing shed");
    assert_eq!(report.shed, 0);
    assert_eq!(report.vectors, report.requests * cfg.batch);

    // the counters the loadgen drove must match exactly; the /metrics
    // request itself is parsed before rendering, hence the +1
    let (status, page) = get(&addr, "/metrics");
    assert_eq!(status, 200);
    let page = String::from_utf8(page).unwrap();
    assert_eq!(
        metric_value(&page, "butterfly_http_requests_total"),
        Some((report.requests + 1) as f64)
    );
    assert_eq!(
        metric_value(&page, "butterfly_apply_requests_total"),
        Some(report.requests as f64)
    );
    assert_eq!(
        metric_value(&page, "butterfly_apply_vectors_total"),
        Some(report.vectors as f64)
    );
    assert_eq!(metric_value(&page, "butterfly_apply_shed_total"), Some(0.0));
    assert_eq!(
        metric_value(&page, "butterfly_route_served_total{route=\"dct\"}"),
        Some(report.vectors as f64)
    );

    server.shutdown_handle().drain();
    let stats = server.join();
    assert_eq!(stats["dct"].served, report.vectors);
    assert_eq!(stats["dct"].in_flight, 0, "quiescent after drain");
    assert_eq!(stats["dct"].queue_depth, 0);
}

#[test]
fn overload_sheds_with_429_and_recovers() {
    let n = 16usize;
    // budget 4 < batch 8: every batch-8 request is shed at admission
    let server = start_server(n, 1, 4);
    let addr = server.local_addr().to_string();

    let shed_cfg = LoadgenConfig {
        addr: addr.clone(),
        route: "dct".into(),
        n,
        complex: false,
        connections: 8,
        requests_per_conn: 5,
        batch: 8,
        seed: 9,
    };
    let report = loadgen::run(&shed_cfg).expect("429s are not client errors");
    assert_eq!(report.requests, 8 * 5, "every request got an answer — no hang");
    assert_eq!(report.shed, report.requests, "batch over budget always sheds");
    assert_eq!(report.ok, 0);

    // batches within budget still flow: the server is healthy, not
    // wedged (one serial connection, so admission is deterministic)
    let ok_cfg = LoadgenConfig { batch: 2, requests_per_conn: 3, connections: 1, ..shed_cfg };
    let report = loadgen::run(&ok_cfg).expect("within-budget load");
    assert_eq!(report.ok, report.requests, "budget admits batch 2");

    server.shutdown_handle().drain();
    let stats = server.join();
    assert_eq!(stats["dct"].served, report.vectors, "only admitted vectors ran");
}

#[test]
fn graceful_drain_completes_every_accepted_request() {
    let n = 16usize;
    let server = start_server(n, 2, 512);
    let addr = server.local_addr().to_string();
    let handle = server.shutdown_handle();

    // write K requests (flushed — on loopback the bytes are in the
    // server's receive buffer once flush returns), THEN drain, then
    // collect: every accepted request must still be answered
    let k = 8usize;
    let conns: Vec<_> = (0..k)
        .map(|i| {
            let stream = TcpStream::connect(&addr).expect("connect");
            stream.set_nodelay(true).ok();
            let read_half = stream.try_clone().expect("clone");
            let mut writer = BufWriter::new(stream);
            let body = format!(
                "{{\"route\":\"dct\",\"re\":[[{}]],\"tag\":{i}}}",
                vec!["1"; n].join(",")
            );
            write!(
                writer,
                "POST /v1/apply HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            )
            .unwrap();
            writer.flush().unwrap();
            (BufReader::new(read_half), writer)
        })
        .collect();
    // wait until the accept loop has registered every connection, so
    // the drain can't beat an accept (then one more breath for the
    // flushed request bytes to be in each connection thread's buffer)
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while (server.metrics().connections_opened.load(std::sync::atomic::Ordering::Relaxed) as usize)
        < k
    {
        assert!(std::time::Instant::now() < deadline, "accept loop stalled");
        std::thread::sleep(Duration::from_millis(2));
    }
    std::thread::sleep(Duration::from_millis(30));
    handle.drain();
    for (i, (mut reader, _writer)) in conns.into_iter().enumerate() {
        let (status, body) = http::read_response(&mut reader).expect("drained request answered");
        assert_eq!(status, 200, "conn {i}: {}", String::from_utf8_lossy(&body));
        let doc = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(doc.get("tag").and_then(|t| t.as_f64()), Some(i as f64));
    }
    let stats = server.join();
    assert_eq!(stats["dct"].served, k, "drain completed every accepted vector");
}

#[test]
fn admin_reload_hot_swaps_mid_traffic() {
    let n = 16usize;
    let server = start_server(n, 2, 512);
    let addr = server.local_addr().to_string();

    // a same-shape (real, n) circulant artifact to swap in
    let mut theta = vec![0.0f32; n];
    Rng::new(77).fill_normal(&mut theta, 0.0, 1.0);
    let art = LayerArtifact {
        name: "swap-target".into(),
        kind: "circulant".into(),
        n,
        depth: 1,
        theta,
        bias: vec![0.0; n],
    };
    let path = std::env::temp_dir().join(format!("bf_net_reload_{}.json", std::process::id()));
    art.save(&path).expect("write artifact");

    let e0_body = format!("{{\"route\":\"dct\",\"re\":[[{}]]}}", {
        let mut v = vec!["0"; n];
        v[0] = "1";
        v.join(",")
    });
    let apply_e0 = |addr: &str| -> Vec<f32> {
        let (status, resp) = post_json(addr, "/v1/apply", &e0_body);
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
        let doc = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
        parse_plane_f32(&doc, "re").remove(0)
    };

    let before = apply_e0(&addr);

    // a bad reload (missing file) is a 400 and must not disturb the route
    let (status, _) = post_json(
        &addr,
        "/admin/reload",
        "{\"route\":\"dct\",\"artifact\":\"/nonexistent/x.json\"}",
    );
    assert_eq!(status, 400);
    assert_eq!(apply_e0(&addr), before, "failed reload left the op untouched");

    let (status, resp) = post_json(
        &addr,
        "/admin/reload",
        &format!("{{\"route\":\"dct\",\"artifact\":{}}}", Json::from(path.to_str().unwrap()).to_string_compact()),
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
    let doc = json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
    assert_eq!(doc.get("n").and_then(|v| v.as_usize()), Some(n));

    // post-swap answers are the circulant op's, bitwise
    let after = apply_e0(&addr);
    let want = {
        let op = art.to_op().unwrap();
        let mut re = vec![0.0f32; n];
        re[0] = 1.0;
        let mut im = Vec::new();
        op.apply_batch(&mut re, &mut im, 1, &mut OpWorkspace::new());
        re
    };
    assert!(
        after.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
        "post-reload answers come from the swapped-in artifact op"
    );
    assert_ne!(after, before, "the swap visibly changed the route");

    // traffic keeps flowing after the swap — a soak burst stays clean
    let report = loadgen::run(&LoadgenConfig {
        addr: addr.clone(),
        route: "dct".into(),
        n,
        complex: false,
        connections: 8,
        requests_per_conn: 4,
        batch: 2,
        seed: 3,
    })
    .expect("post-reload traffic");
    assert_eq!(report.ok, report.requests);

    server.shutdown_handle().drain();
    server.join();
    std::fs::remove_file(&path).ok();
}
