//! Integration: learn → harden → install → serve. The full lifecycle of
//! the paper's system used as a serving stack.

use butterfly::butterfly::closed_form::{convolution_stack, dft_stack, hadamard_stack};
use butterfly::butterfly::params::PermTying;
use butterfly::coordinator::trial::Trial;
use butterfly::coordinator::{FactorizeJob, TrialConfig};
use butterfly::runtime::engine::unpack_stack;
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::op::stack_op;
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;
use std::time::Duration;

#[test]
fn learned_transform_served_end_to_end() {
    // 1. learn a DFT factorization (native trial)
    let n = 8;
    let job = FactorizeJob::paper(TransformKind::Dft, n, 42, 2000);
    let mut best: Option<Trial> = None;
    for seed in 1..=5 {
        let cfg = TrialConfig { lr: 0.05, seed, perm_tying: PermTying::Untied };
        let mut t = Trial::new(&job, cfg);
        let r = t.advance(1500, 1e-4);
        if best.as_ref().map_or(true, |b| r < b.last_loss.sqrt()) {
            best = Some(t);
        }
    }
    let trial = best.unwrap();
    let rmse = trial.rmse();
    // 2. round-trip through the theta interchange (what the coordinator
    //    hands to serving)
    let theta = butterfly::runtime::engine::pack_stack(&trial.canonical_stack());
    let stack = unpack_stack(n, 1, &theta);
    // 3. install + serve
    let mut router = Router::new();
    router.install("learned-dft", stack_op("learned-dft", &stack), 1, BatcherConfig::default());
    let target = &job.target;
    let mut worst = 0.0f32;
    for j in 0..n {
        let mut x = vec![0.0f32; n];
        x[j] = 1.0;
        let (re, im) = router.call("learned-dft", x, vec![0.0; n]).unwrap();
        for i in 0..n {
            worst = worst.max((re[i] - target.re[i * n + j]).abs());
            worst = worst.max((im[i] - target.im[i * n + j]).abs());
        }
    }
    // serving applies the HARDENED permutation; only meaningful when the
    // trial converged to a peaked factorization
    eprintln!("trial rmse {rmse:.2e}, served max err {worst:.2e}, confidence {:.3}", trial.perm_confidence());
    if rmse < 1e-3 && trial.perm_confidence() > 0.95 {
        assert!(worst < 0.05, "served error {worst}");
    }
    router.shutdown();
}

#[test]
fn multi_transform_router_under_load() {
    let n = 64;
    let mut rng = Rng::new(3);
    let mut h = vec![0.0f32; n];
    rng.fill_normal(&mut h, 0.0, (1.0 / n as f64).sqrt() as f32);
    let mut router = Router::new();
    let cfg = BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(1), queue_cap: 4096 };
    router.install("dft", stack_op("dft", &dft_stack(n)), 2, cfg.clone());
    router.install("hadamard", stack_op("hadamard", &hadamard_stack(n)), 1, cfg.clone());
    router.install("conv", stack_op("conv", &convolution_stack(&h)), 1, cfg);
    let names = ["dft", "hadamard", "conv"];
    let threads: Vec<_> = (0..6)
        .map(|t| {
            let handle = router.handle(names[t % 3]).unwrap();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                for _ in 0..50 {
                    let mut x = vec![0.0f32; 64];
                    rng.fill_normal(&mut x, 0.0, 1.0);
                    let (re, im) = handle.call(x, vec![0.0; 64]).unwrap();
                    assert!(re.iter().chain(im.iter()).all(|v| v.is_finite()));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let stats = router.shutdown();
    let total: usize = stats.values().map(|s| s.served).sum();
    assert_eq!(total, 300);
    assert_eq!(stats["dft"].served, 100);
}

#[test]
fn backpressure_rejects_rather_than_grows() {
    let n = 1024;
    // a deliberately tiny queue + slow-ish service (large n)
    let svc = butterfly::serving::ServicePool::spawn(
        "dft",
        stack_op("dft", &dft_stack(n)),
        2,
        BatcherConfig { max_batch: 2, max_wait: Duration::from_micros(50), queue_cap: 4 },
    );
    let h = svc.handle();
    let producers: Vec<_> = (0..8)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rejected = 0usize;
                let mut ok = 0usize;
                let mut rng = Rng::new(t);
                for _ in 0..40 {
                    let mut x = vec![0.0f32; n];
                    rng.fill_normal(&mut x, 0.0, 1.0);
                    match h.call_real(x) {
                        Ok(_) => ok += 1,
                        Err(_) => rejected += 1,
                    }
                }
                (ok, rejected)
            })
        })
        .collect();
    let mut total_ok = 0;
    let mut total_rej = 0;
    for p in producers {
        let (ok, rej) = p.join().unwrap();
        total_ok += ok;
        total_rej += rej;
    }
    let stats = svc.shutdown();
    assert_eq!(stats.served, total_ok);
    assert_eq!(stats.rejected, total_rej);
    assert_eq!(total_ok + total_rej, 320);
    assert!(total_ok > 0);
}
