//! Integration: end-to-end transform recovery on the native engine — a
//! fast-path version of the paper's §4.1 experiment at small N (the full
//! grid lives in `examples/transform_zoo.rs` and `benches/fig3_recovery`).

use butterfly::butterfly::params::PermTying;
use butterfly::butterfly::permutation::{hard_perm_table, RelaxedPerm};
use butterfly::coordinator::trial::Trial;
use butterfly::coordinator::{FactorizeJob, TrialConfig};
use butterfly::transforms::fast::bit_reversal_table;
use butterfly::transforms::spec::TransformKind;

fn recover(kind: TransformKind, n: usize, lr: f32, steps: usize, seed: u64) -> (Trial, f64) {
    let job = FactorizeJob::paper(kind, n, seed, steps);
    let cfg = TrialConfig { lr, seed: seed.wrapping_mul(7919), perm_tying: PermTying::Untied };
    let mut t = Trial::new(&job, cfg);
    let rmse = t.advance(steps, 1e-5);
    (t, rmse)
}

#[test]
fn dft_n8_reaches_near_machine_precision() {
    // small lr × seed sweep — at least one should land a clean
    // factorization (the full-budget Hyperband version reaches 1e-4;
    // see benches/fig3_recovery)
    let mut best = f64::INFINITY;
    'outer: for lr in [0.05f32, 0.1, 0.02] {
        for seed in 1..=4 {
            let (_, rmse) = recover(TransformKind::Dft, 8, lr, 3000, seed);
            best = best.min(rmse);
            if best < 1e-4 {
                break 'outer;
            }
        }
    }
    assert!(best < 1e-3, "best rmse over seeds: {best}");
}

#[test]
fn hadamard_n16_recovers() {
    let mut best = f64::INFINITY;
    for seed in 1..=4 {
        let (_, rmse) = recover(TransformKind::Hadamard, 16, 0.05, 1500, seed);
        best = best.min(rmse);
        if best < 1e-4 {
            break;
        }
    }
    assert!(best < 5e-3, "best rmse over seeds: {best}");
}

#[test]
fn learned_dft_permutation_hardens_to_a_valid_factorization() {
    // After training, harden the permutation and keep training the
    // twiddles — RMSE should stay low, i.e. the soft perm actually
    // converged to a *discrete* algorithm (§4.1: the method "recovers
    // the bit-reversal permutation … [and] many other unconventional
    // permutations that also lead to exact factorization").
    let mut best: Option<Trial> = None;
    for seed in 1..=6 {
        let (t, rmse) = recover(TransformKind::Dft, 8, 0.05, 1200, seed);
        if rmse < best.as_ref().map_or(f64::INFINITY, |b| b.last_loss.sqrt()) {
            best = Some(t);
        }
    }
    let t = best.unwrap();
    let rmse = t.rmse();
    if rmse > 1e-3 {
        eprintln!("SKIP harden check: no good factorization found (rmse {rmse})");
        return;
    }
    // confidence: gates should be peaked (paper reports ≥ 0.99)
    assert!(t.perm_confidence() > 0.9, "confidence {}", t.perm_confidence());
    let choices = RelaxedPerm::harden(&t.stack.modules[0].params);
    let table = hard_perm_table(8, &choices);
    // the hardened choice is *a* permutation — often bit-reversal
    let is_bitrev = table == bit_reversal_table(8);
    eprintln!("hardened perm {table:?} (bit-reversal: {is_bitrev})");
}

#[test]
fn randn_is_not_recoverable() {
    // the unstructured control row of Figure 3: butterfly cannot fit it
    let (_, rmse) = recover(TransformKind::Randn, 16, 0.03, 800, 3);
    assert!(rmse > 5e-2, "randn rmse suspiciously low: {rmse}");
}

#[test]
fn legendre_partially_recoverable() {
    // paper: DLT not perfectly captured, but better than unstructured
    let (_, leg) = recover(TransformKind::Legendre, 16, 0.03, 800, 3);
    let (_, rnd) = recover(TransformKind::Randn, 16, 0.03, 800, 3);
    assert!(leg < rnd, "legendre {leg} should beat randn {rnd}");
}

#[test]
fn convolution_uses_bpbp_and_improves_over_bp() {
    let n = 8;
    let steps = 1200;
    let job2 = FactorizeJob::paper(TransformKind::Convolution, n, 5, steps);
    assert_eq!(job2.depth, 2);
    let cfg = TrialConfig { lr: 0.04, seed: 17, perm_tying: PermTying::Untied };
    let mut bpbp = Trial::new(&job2, cfg);
    let r2 = bpbp.advance(steps, 1e-5);
    let mut job1 = job2.clone();
    job1.depth = 1;
    let mut bp = Trial::new(&job1, cfg);
    let r1 = bp.advance(steps, 1e-5);
    assert!(r2 < r1, "BPBP ({r2}) should beat BP ({r1}) on convolution");
}
