//! Integration: Proposition 1 end-to-end — closed-form BP/BP² stacks vs
//! dense targets at paper-scale N, through every execution surface
//! (dense reconstruction, module apply, hardened fast path, theta
//! interchange).

use butterfly::butterfly::closed_form::{
    closed_form_stack, convolution_stack, dct_stack, dft_stack, dst_stack, hadamard_stack, CompareMode,
};
use butterfly::butterfly::fast::{FastBp, Workspace};
use butterfly::linalg::dense::Mat;
use butterfly::runtime::engine::{pack_stack, unpack_stack};
use butterfly::transforms::matrices;
use butterfly::transforms::spec::{TransformKind, ALL_TRANSFORMS};
use butterfly::util::rng::Rng;

fn real_plane_rmse(m: &butterfly::linalg::dense::CMat, t: &Mat) -> f64 {
    let n = m.rows;
    let mut acc = 0.0f64;
    for i in 0..n * n {
        let d = (m.re[i] - t.data[i]) as f64;
        acc += d * d;
    }
    (acc / (n * n) as f64).sqrt()
}

#[test]
fn prop1_at_paper_scale_n1024() {
    // DFT and Hadamard exactly in (BP)¹ at N = 1024 (the paper's largest)
    let n = 1024;
    let dft = dft_stack(n);
    assert_eq!(dft.depth(), 1);
    let e = dft.to_matrix().rmse_to(&matrices::dft_matrix(n));
    assert!(e < 1e-4, "DFT n=1024 rmse {e}");
    let had = hadamard_stack(n);
    let e = had.to_matrix().rmse_to(&matrices::hadamard_matrix(n).to_cmat());
    assert!(e < 1e-5, "Hadamard n=1024 rmse {e}");
}

#[test]
fn prop1_bp2_members_at_n512() {
    let n = 512;
    let e = real_plane_rmse(&dct_stack(n).to_matrix(), &matrices::dct_matrix(n));
    assert!(e < 1e-4, "DCT rmse {e}");
    let e = real_plane_rmse(&dst_stack(n).to_matrix(), &matrices::dst_matrix(n));
    assert!(e < 1e-4, "DST rmse {e}");
    let mut rng = Rng::new(1);
    let mut h = vec![0.0f32; n];
    rng.fill_normal(&mut h, 0.0, (1.0 / n as f64).sqrt() as f32);
    let e = convolution_stack(&h).to_matrix().rmse_to(&matrices::circulant_matrix(&h).to_cmat());
    assert!(e < 1e-5, "conv rmse {e}");
}

#[test]
fn fast_path_equals_dense_reconstruction() {
    let n = 256;
    let stack = dft_stack(n);
    let fast = FastBp::from_stack(&stack);
    let m = stack.to_matrix();
    let mut ws = Workspace::new(n);
    let mut rng = Rng::new(4);
    let mut re = vec![0.0f32; n];
    let mut im = vec![0.0f32; n];
    rng.fill_normal(&mut re, 0.0, 1.0);
    rng.fill_normal(&mut im, 0.0, 1.0);
    let x: Vec<butterfly::linalg::complex::Cpx> =
        re.iter().zip(&im).map(|(&r, &i)| butterfly::linalg::complex::Cpx::new(r, i)).collect();
    let want = m.matvec(&x);
    fast.apply_complex(&mut re, &mut im, &mut ws);
    for i in 0..n {
        assert!((re[i] - want[i].re).abs() < 1e-3);
        assert!((im[i] - want[i].im).abs() < 1e-3);
    }
}

#[test]
fn theta_interchange_preserves_closed_forms() {
    let n = 64;
    let stack = dft_stack(n);
    let theta = pack_stack(&stack);
    let back = unpack_stack(n, 1, &theta);
    let e = back.to_matrix().rmse_to(&matrices::dft_matrix(n));
    assert!(e < 1e-6, "roundtrip rmse {e}");
}

#[test]
fn closed_form_coverage_matches_spec() {
    let mut rng = Rng::new(7);
    for kind in ALL_TRANSFORMS {
        match closed_form_stack(kind, 32, &mut rng) {
            Some((stack, mode)) => {
                let m = stack.to_matrix();
                let mut rng2 = Rng::new(7);
                // regenerate target with a fresh rng stream mirroring
                // closed_form_stack's own draw for stochastic targets
                let target = matrices::target_matrix(kind, 32, &mut rng2);
                let e = match mode {
                    CompareMode::Exact => m.rmse_to(&target),
                    CompareMode::RealPart => {
                        let t = Mat { rows: 32, cols: 32, data: target.re.clone() };
                        real_plane_rmse(&m, &t)
                    }
                };
                assert!(e < 1e-5, "{kind}: rmse {e}");
            }
            None => {
                assert!(matches!(
                    kind,
                    TransformKind::Hartley | TransformKind::Legendre | TransformKind::Randn
                ));
            }
        }
    }
}
