//! Integration: the coordinator (scheduler + registry + metrics) across
//! whole jobs, including parallel execution and early stopping.

use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig, TrialStatus};
use butterfly::transforms::spec::TransformKind;

#[test]
fn full_job_bookkeeping() {
    let job = FactorizeJob::paper(TransformKind::Dft, 8, 42, 3000);
    let cfg = SchedulerConfig { workers: 4, max_resource: 9, eta: 3, step_quantum: 25, seed: 11 };
    let metrics = Metrics::new();
    let registry = Registry::new();
    let res = run_job(&job, &cfg, &metrics, &registry);
    let snap = metrics.snapshot();
    assert_eq!(snap.jobs_completed, 1);
    assert_eq!(snap.trials_started, res.trials_run);
    assert!(snap.steps_total > 0);
    assert_eq!(snap.steps_total, res.total_steps);
    // train time accumulates from inside Trial::advance; job time is the
    // whole-job wall clock (the two are distinct counters now)
    assert!(snap.train_micros > 0);
    assert!(snap.job_micros > 0);
    // registry is consistent: every trial has a record, statuses partition
    assert_eq!(registry.len(), res.trials_run);
    let done = registry.count_status(TrialStatus::Completed);
    let pruned = registry.count_status(TrialStatus::Pruned);
    let running = registry.count_status(TrialStatus::Running);
    let cancelled = registry.count_status(TrialStatus::Cancelled);
    assert_eq!(done + pruned + running + cancelled, res.trials_run);
    // leaderboard best matches result
    let lb = registry.leaderboard();
    assert!((lb[0].rmse - res.best_rmse).abs() < 1e-9 || res.best_rmse <= lb[0].rmse);
}

#[test]
fn early_stop_saves_budget() {
    // identity target is trivially representable: the job should stop
    // long before exhausting the hyperband budget
    let mut job = FactorizeJob::paper(TransformKind::Hadamard, 8, 1, 100_000);
    job.target = butterfly::linalg::dense::CMat::eye(8);
    job.target_rmse = 5e-2; // loose: near-orthogonal init + few steps
    let cfg = SchedulerConfig { workers: 2, max_resource: 27, eta: 3, step_quantum: 50, seed: 3 };
    let metrics = Metrics::new();
    let registry = Registry::new();
    let res = run_job(&job, &cfg, &metrics, &registry);
    assert!(res.reached_target, "rmse {}", res.best_rmse);
    assert_eq!(metrics.snapshot().targets_reached, 1);
}

#[test]
fn workers_parameter_changes_nothing_about_results_shape() {
    // determinism of the *sampled configs* (same seed) regardless of
    // worker count; rmse may differ by execution order of fp ops only
    for workers in [1usize, 4] {
        let job = FactorizeJob::paper(TransformKind::Dct, 8, 9, 600);
        let cfg = SchedulerConfig { workers, max_resource: 9, eta: 3, step_quantum: 10, seed: 5 };
        let registry = Registry::new();
        let res = run_job(&job, &cfg, &Metrics::new(), &registry);
        assert!(res.best_rmse.is_finite());
        assert!(res.best_theta.len() > 0);
    }
}

#[test]
fn multi_job_campaign_accumulates_metrics() {
    let metrics = Metrics::new();
    let cfg = SchedulerConfig { workers: 2, max_resource: 3, eta: 3, step_quantum: 10, seed: 2 };
    for kind in [TransformKind::Dft, TransformKind::Hadamard, TransformKind::Dct] {
        let job = FactorizeJob::paper(kind, 8, 7, 400);
        let registry = Registry::new();
        run_job(&job, &cfg, &metrics, &registry);
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.jobs_completed, 3);
    assert!(snap.trials_started >= 9);
}
