//! HTTP-layer property tests against a live loopback server: malformed
//! request lines, oversized headers/bodies, truncated writes, pipelined
//! keep-alive, and bad JSON — every one must map to the documented
//! status (400/413) or a silent close, and none may wedge or kill the
//! server. The in-memory equivalents live in `net::http`'s unit tests;
//! this suite proves the connection loop wires them to real sockets.

use butterfly::net::http;
use butterfly::net::{Server, ServerConfig};
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::op::plan;
use butterfly::transforms::spec::TransformKind;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

fn start_server(max_connections: usize) -> Server {
    let mut router = Router::new();
    router.install("dct", plan(TransformKind::Dct, 8), 1, BatcherConfig::default());
    Server::start(
        router,
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            max_connections,
            inflight_budget: 512,
            adaptive_cap: None,
            fuse: None,
        },
    )
    .expect("bind loopback")
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Conn {
    fn open(addr: &str) -> Conn {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone().expect("clone");
        Conn { reader: BufReader::new(read_half), writer: BufWriter::new(stream) }
    }

    fn send(&mut self, raw: &[u8]) {
        self.writer.write_all(raw).unwrap();
        self.writer.flush().unwrap();
    }

    fn response(&mut self) -> (u16, Vec<u8>) {
        http::read_response(&mut self.reader).expect("response")
    }

    /// True when the server closed the connection (clean EOF).
    fn at_eof(&mut self) -> bool {
        matches!(self.reader.fill_buf(), Ok(buf) if buf.is_empty())
    }
}

fn server_is_alive(addr: &str) {
    let mut c = Conn::open(addr);
    c.send(b"GET /healthz HTTP/1.1\r\n\r\n");
    let (status, body) = c.response();
    assert_eq!(status, 200);
    assert_eq!(body, b"ok\n");
}

#[test]
fn malformed_request_lines_get_400_then_close() {
    let server = start_server(64);
    let addr = server.local_addr().to_string();
    let bads: [&[u8]; 5] = [
        b"GARBAGE\r\n\r\n",
        b"GET /healthz HTTP/2.0\r\n\r\n",
        b"get /healthz HTTP/1.1\r\n\r\n",
        b"GET healthz HTTP/1.1\r\n\r\n",
        b"\xff\xfe\xfd bytes that are not utf-8\r\n\r\n",
    ];
    for raw in bads {
        let mut c = Conn::open(&addr);
        c.send(raw);
        let (status, _) = c.response();
        assert_eq!(status, 400, "{:?}", String::from_utf8_lossy(raw));
        assert!(c.at_eof(), "protocol violations close the connection");
    }
    server_is_alive(&addr);
    server.shutdown_handle().drain();
    server.join();
}

#[test]
fn oversize_inputs_get_413() {
    let server = start_server(64);
    let addr = server.local_addr().to_string();
    // request line far over the 8K limit
    let mut c = Conn::open(&addr);
    c.send(format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(10_000)).as_bytes());
    assert_eq!(c.response().0, 413);
    // one oversized header line
    let mut c = Conn::open(&addr);
    c.send(format!("GET /healthz HTTP/1.1\r\nx-big: {}\r\n\r\n", "b".repeat(10_000)).as_bytes());
    assert_eq!(c.response().0, 413);
    // too many headers
    let mut c = Conn::open(&addr);
    let mut raw = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..100 {
        raw.push_str(&format!("x-h{i}: v\r\n"));
    }
    raw.push_str("\r\n");
    c.send(raw.as_bytes());
    assert_eq!(c.response().0, 413);
    // declared body over the cap — rejected from the header alone, no
    // body bytes ever sent
    let mut c = Conn::open(&addr);
    c.send(b"POST /v1/apply HTTP/1.1\r\ncontent-length: 9000000\r\n\r\n");
    assert_eq!(c.response().0, 413);
    server_is_alive(&addr);
    server.shutdown_handle().drain();
    server.join();
}

#[test]
fn truncated_and_stalled_requests_are_dropped_not_fatal() {
    let server = start_server(64);
    let addr = server.local_addr().to_string();
    // body cut short, then close: no response, just a dropped connection
    {
        let mut c = Conn::open(&addr);
        c.send(b"POST /v1/apply HTTP/1.1\r\ncontent-length: 100\r\n\r\n{\"ro");
    } // drop closes our half
    // headers cut short, then close
    {
        let mut c = Conn::open(&addr);
        c.send(b"GET /healthz HTTP/1.1\r\ncontent-");
    }
    // a stalled mid-request connection (bytes written, then silence)
    // outlives the read timeout and is dropped without desynchronizing
    // anything else
    let mut stalled = Conn::open(&addr);
    stalled.send(b"POST /v1/apply HTTP/1.1\r\ncontent-le");
    std::thread::sleep(Duration::from_millis(450));
    let mut probe = [0u8; 1];
    let n = stalled.reader.read(&mut probe).unwrap_or(0);
    assert_eq!(n, 0, "stalled connection closed with no response bytes");
    server_is_alive(&addr);
    server.shutdown_handle().drain();
    server.join();
}

#[test]
fn pipelined_keep_alive_requests_answer_in_order() {
    let server = start_server(64);
    let addr = server.local_addr().to_string();
    let mut c = Conn::open(&addr);
    let body = r#"{"route":"dct","re":[[1,0,0,0,0,0,0,0]],"tag":42}"#;
    let mut raw = String::new();
    raw.push_str("GET /healthz HTTP/1.1\r\n\r\n");
    raw.push_str(&format!(
        "POST /v1/apply HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    ));
    raw.push_str("GET /v1/routes HTTP/1.1\r\n\r\n");
    c.send(raw.as_bytes());
    let (s1, b1) = c.response();
    assert_eq!((s1, b1.as_slice()), (200, b"ok\n".as_slice()));
    let (s2, b2) = c.response();
    assert_eq!(s2, 200);
    assert!(String::from_utf8_lossy(&b2).contains("\"tag\":42"));
    let (s3, b3) = c.response();
    assert_eq!(s3, 200);
    assert!(String::from_utf8_lossy(&b3).contains("\"name\":\"dct\""));
    server.shutdown_handle().drain();
    server.join();
}

#[test]
fn bad_json_is_400_and_keeps_the_connection_and_server() {
    let server = start_server(64);
    let addr = server.local_addr().to_string();
    let mut c = Conn::open(&addr);
    // bad JSON is an application-level 400 — well-formed HTTP, so the
    // keep-alive connection survives and the next request answers
    let bad = "{\"route\":\"dct\",\"re\":[[1,2,";
    c.send(
        format!("POST /v1/apply HTTP/1.1\r\ncontent-length: {}\r\n\r\n{bad}", bad.len())
            .as_bytes(),
    );
    let (status, body) = c.response();
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("error"));
    let good = r#"{"route":"dct","re":[[0,1,0,0,0,0,0,0]]}"#;
    c.send(
        format!("POST /v1/apply HTTP/1.1\r\ncontent-length: {}\r\n\r\n{good}", good.len())
            .as_bytes(),
    );
    assert_eq!(c.response().0, 200, "same connection serves after a 400");
    server_is_alive(&addr);
    server.shutdown_handle().drain();
    server.join();
}

#[test]
fn connection_cap_answers_503_with_retry_after() {
    let server = start_server(1);
    let addr = server.local_addr().to_string();
    let mut a = Conn::open(&addr);
    a.send(b"GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(a.response().0, 200, "first connection is in");
    let mut b = Conn::open(&addr);
    b.send(b"GET /healthz HTTP/1.1\r\n\r\n");
    let (status, _) = b.response();
    assert_eq!(status, 503, "second connection is over the cap");
    assert!(b.at_eof(), "refused connections are closed");
    drop(a);
    drop(b);
    // once the parked connection notices the close (≤ one read timeout)
    // a newcomer fits under the cap again
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let mut c = Conn::open(&addr);
        c.send(b"GET /healthz HTTP/1.1\r\n\r\n");
        if c.response().0 == 200 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "slot never freed");
        std::thread::sleep(Duration::from_millis(20));
    }
    server.shutdown_handle().drain();
    server.join();
}
