//! Integration: Figure-3's comparison logic — butterfly vs sparse vs
//! low-rank vs sparse+low-rank at EQUAL multiplication budget, on real
//! transform targets. Checks the *shape* of the paper's result: the
//! butterfly wins on recursive transforms and everything fails on the
//! unstructured control.

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline, sparse_plus_lowrank_baseline};
use butterfly::butterfly::params::PermTying;
use butterfly::coordinator::trial::Trial;
use butterfly::coordinator::{FactorizeJob, TrialConfig};
use butterfly::transforms::matrices::target_matrix;
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;

fn butterfly_rmse(kind: TransformKind, n: usize) -> f64 {
    let mut best = f64::INFINITY;
    for seed in 1..=3 {
        let job = FactorizeJob::paper(kind, n, 11, 900);
        let cfg = TrialConfig { lr: 0.05, seed, perm_tying: PermTying::Untied };
        let mut t = Trial::new(&job, cfg);
        best = best.min(t.advance(900, 1e-5));
        if best < 1e-4 {
            break;
        }
    }
    best
}

#[test]
fn butterfly_beats_baselines_on_dft() {
    let n = 16;
    let mut rng = Rng::new(11);
    let target = target_matrix(TransformKind::Dft, n, &mut rng);
    let budget = butterfly_budget(n, 1);
    let bf = butterfly_rmse(TransformKind::Dft, n);
    let sp = sparse_baseline(&target, budget).rmse;
    let lr = lowrank_baseline(&target, budget).rmse;
    let both = sparse_plus_lowrank_baseline(&target, budget).rmse;
    eprintln!("DFT n={n}: butterfly {bf:.2e}, sparse {sp:.2e}, lowrank {lr:.2e}, s+l {both:.2e}");
    assert!(bf < sp / 5.0, "butterfly {bf} vs sparse {sp}");
    assert!(bf < lr / 5.0, "butterfly {bf} vs lowrank {lr}");
    assert!(bf < both / 5.0, "butterfly {bf} vs sparse+lowrank {both}");
}

#[test]
fn baselines_cannot_fit_hadamard_at_budget() {
    // |H_kn| = 1/√N everywhere: dense energy spread defeats both
    // sparsity and low rank
    let n = 64;
    let mut rng = Rng::new(5);
    let target = target_matrix(TransformKind::Hadamard, n, &mut rng);
    let budget = butterfly_budget(n, 1);
    assert!(sparse_baseline(&target, budget).rmse > 1e-2);
    assert!(lowrank_baseline(&target, budget).rmse > 5e-2);
}

#[test]
fn nobody_fits_randn() {
    // the control row: every method should plateau at a large error
    let n = 32;
    let mut rng = Rng::new(9);
    let target = target_matrix(TransformKind::Randn, n, &mut rng);
    let budget = butterfly_budget(n, 1);
    let sp = sparse_baseline(&target, budget).rmse;
    let lr = lowrank_baseline(&target, budget).rmse;
    assert!(sp > 1e-2, "sparse {sp}");
    assert!(lr > 1e-2, "lowrank {lr}");
}

#[test]
fn equal_budget_accounting() {
    // all three baselines are held to the butterfly budget or less
    let n = 32;
    let mut rng = Rng::new(2);
    let target = target_matrix(TransformKind::Dct, n, &mut rng);
    let budget = butterfly_budget(n, 1);
    assert!(sparse_baseline(&target, budget).used_budget <= budget);
    assert!(lowrank_baseline(&target, budget).used_budget <= budget);
    let b = sparse_plus_lowrank_baseline(&target, budget);
    assert!(b.used_budget <= budget + 2 * n, "s+l used {}", b.used_budget);
}
