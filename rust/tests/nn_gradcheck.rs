//! Finite-difference gradient checks for every `nn/` layer, run against
//! the workspace (`*_ws`) kernels — the same kernels the legacy `Layer`
//! trait delegates to, so one sweep covers both surfaces.
//!
//! Method: central differences on a quadratic objective
//! `L = Σ y² / 2` (f64-accumulated). Every layer here is *linear* in
//! each individual parameter and in the input, so `L` is exactly
//! quadratic along any single coordinate and the central difference has
//! **zero truncation error** — the only discrepancy is f32 forward
//! rounding, which the tolerance `2e-3 · (1 + |∂|)` dominates by a wide
//! margin at these sizes. The softmax-CE head (not quadratic) gets its
//! own check at a smaller step. All seeds fixed.

use butterfly::butterfly::params::Field;
use butterfly::butterfly::permutation::PermTables;
use butterfly::nn::layers::softmax_cross_entropy;
use butterfly::nn::{ButterflyLayer, CirculantLayer, DenseLayer, Layer, LowRankLayer, ReluLayer};
use butterfly::util::rng::Rng;

const EPS: f32 = 1e-2;

fn quad_loss(y: &[f32]) -> f64 {
    y.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
}

fn check(label: &str, fd: f32, an: f32) {
    let tol = 2e-3 * (1.0 + fd.abs().max(an.abs()));
    assert!((fd - an).abs() < tol, "{label}: fd {fd} vs analytic {an} (tol {tol})");
}

// ---------------------------------------------------------------------
// dense (weights, bias, input)
// ---------------------------------------------------------------------

#[test]
fn dense_ws_gradcheck() {
    let mut rng = Rng::new(101);
    let (in_dim, out_dim, batch) = (6, 5, 3);
    let mut l = DenseLayer::new(in_dim, out_dim, &mut rng);
    let mut x = vec![0.0f32; batch * in_dim];
    rng.fill_normal(&mut x, 0.0, 0.7);

    let loss = |l: &DenseLayer, x: &[f32]| -> f64 {
        let mut y = vec![0.0f32; batch * out_dim];
        l.forward_ws(x, &mut y, batch);
        quad_loss(&y)
    };
    let mut y = vec![0.0f32; batch * out_dim];
    l.forward_ws(&x, &mut y, batch);
    let dy = y.clone(); // dL/dy = y for the quadratic objective
    let mut dx = vec![0.0f32; batch * in_dim];
    let mut g = vec![0.0f32; l.grad_len()];
    l.backward_ws(&x, &dy, &mut dx, &mut g, batch);

    for i in 0..in_dim * out_dim {
        let o = l.w[i];
        l.w[i] = o + EPS;
        let lp = loss(&l, &x);
        l.w[i] = o - EPS;
        let lm = loss(&l, &x);
        l.w[i] = o;
        check(&format!("dense w[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[i]);
    }
    for i in 0..out_dim {
        let o = l.b[i];
        l.b[i] = o + EPS;
        let lp = loss(&l, &x);
        l.b[i] = o - EPS;
        let lm = loss(&l, &x);
        l.b[i] = o;
        check(&format!("dense b[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[in_dim * out_dim + i]);
    }
    for i in 0..x.len() {
        let o = x[i];
        x[i] = o + EPS;
        let lp = loss(&l, &x);
        x[i] = o - EPS;
        let lm = loss(&l, &x);
        x[i] = o;
        check(&format!("dense x[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, dx[i]);
    }
}

// ---------------------------------------------------------------------
// low-rank (both factors, input)
// ---------------------------------------------------------------------

#[test]
fn lowrank_ws_gradcheck() {
    let mut rng = Rng::new(102);
    let (n, rank, batch) = (6, 2, 3);
    let mut l = LowRankLayer::new(n, n, rank, &mut rng);
    let mut x = vec![0.0f32; batch * n];
    rng.fill_normal(&mut x, 0.0, 0.7);

    let loss = |l: &LowRankLayer, x: &[f32]| -> f64 {
        let mut mid = vec![0.0f32; batch * rank];
        let mut y = vec![0.0f32; batch * n];
        l.forward_ws(x, &mut mid, &mut y, batch);
        quad_loss(&y)
    };
    let mut mid = vec![0.0f32; batch * rank];
    let mut y = vec![0.0f32; batch * n];
    l.forward_ws(&x, &mut mid, &mut y, batch);
    let dy = y.clone();
    let mut dmid = vec![0.0f32; batch * rank];
    let mut dx = vec![0.0f32; batch * n];
    let mut g = vec![0.0f32; l.grad_len()];
    l.backward_ws(&x, &mid, &dy, &mut dmid, &mut dx, &mut g, batch);

    let v_grad_len = l.factors().0.grad_len();
    // V weights sit at the head of the flat gradient, U weights after
    for i in (0..rank * n).step_by(2) {
        let o = l.factors().0.w[i];
        l.factors_mut().0.w[i] = o + EPS;
        let lp = loss(&l, &x);
        l.factors_mut().0.w[i] = o - EPS;
        let lm = loss(&l, &x);
        l.factors_mut().0.w[i] = o;
        check(&format!("lowrank v[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[i]);
    }
    for i in (0..n * rank).step_by(2) {
        let o = l.factors().1.w[i];
        l.factors_mut().1.w[i] = o + EPS;
        let lp = loss(&l, &x);
        l.factors_mut().1.w[i] = o - EPS;
        let lm = loss(&l, &x);
        l.factors_mut().1.w[i] = o;
        check(&format!("lowrank u[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[v_grad_len + i]);
    }
    for i in 0..x.len() {
        let o = x[i];
        x[i] = o + EPS;
        let lp = loss(&l, &x);
        x[i] = o - EPS;
        let lm = loss(&l, &x);
        x[i] = o;
        check(&format!("lowrank x[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, dx[i]);
    }
}

// ---------------------------------------------------------------------
// ReLU (input gradient through the legacy path; no parameters)
// ---------------------------------------------------------------------

#[test]
fn relu_gradcheck_away_from_kink() {
    let mut rng = Rng::new(103);
    let mut r = ReluLayer::new();
    // keep every coordinate at least 10·EPS from the kink
    let x: Vec<f32> = (0..12)
        .map(|_| {
            let v = rng.normal_f32(0.0, 1.0);
            v + v.signum() * 0.2
        })
        .collect();
    let y = r.forward(&x, 1, true);
    let dy = y.clone();
    let dx = r.backward(&dy, 1);
    for i in 0..x.len() {
        let mut xp = x.clone();
        xp[i] += EPS;
        let lp = quad_loss(&r.forward(&xp, 1, false));
        xp[i] -= 2.0 * EPS;
        let lm = quad_loss(&r.forward(&xp, 1, false));
        check(&format!("relu x[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, dx[i]);
    }
}

// ---------------------------------------------------------------------
// softmax cross-entropy (logit gradient)
// ---------------------------------------------------------------------

#[test]
fn softmax_ce_gradcheck() {
    let mut rng = Rng::new(104);
    let (batch, classes) = (3, 5);
    let mut logits = vec![0.0f32; batch * classes];
    rng.fill_normal(&mut logits, 0.0, 1.5);
    let labels: Vec<u8> = (0..batch).map(|i| ((i * 2) % classes) as u8).collect();
    let (_, dl, _) = softmax_cross_entropy(&logits, &labels, batch, classes);
    let eps = 1e-3f32;
    for i in 0..logits.len() {
        let o = logits[i];
        logits[i] = o + eps;
        let (lp, _, _) = softmax_cross_entropy(&logits, &labels, batch, classes);
        logits[i] = o - eps;
        let (lm, _, _) = softmax_cross_entropy(&logits, &labels, batch, classes);
        logits[i] = o;
        check(&format!("softmax logit[{i}]"), (lp - lm) / (2.0 * eps), dl[i]);
    }
}

// ---------------------------------------------------------------------
// butterfly (real + complex, depth 1 and 2; twiddles, bias, input)
// ---------------------------------------------------------------------

fn butterfly_gradcheck(field: Field, depth: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let n = 8;
    let batch = 2;
    let mut layer = ButterflyLayer::new(n, depth, field, &mut rng);
    rng.fill_normal(&mut layer.bias, 0.0, 0.3);
    let mut x = vec![0.0f32; batch * n];
    rng.fill_normal(&mut x, 0.0, 0.7);
    let tables = PermTables::new(n);
    let len = batch * n;

    let loss = |layer: &ButterflyLayer, x: &[f32]| -> f64 {
        let mut y = vec![0.0f32; len];
        let mut im = vec![0.0f32; len];
        let (mut sr, mut si) = (vec![0.0f32; len], vec![0.0f32; len]);
        layer.infer_ws(x, &mut y, &mut im, batch, &tables, &mut sr, &mut si);
        quad_loss(&y)
    };

    // analytic gradients through the workspace training path
    let mut y = vec![0.0f32; len];
    let mut im = vec![0.0f32; len];
    let (mut sr, mut si) = (vec![0.0f32; len], vec![0.0f32; len]);
    let mut saves = Vec::new();
    layer.forward_train_ws(&x, &mut y, &mut im, batch, &mut saves, &tables, &mut sr, &mut si);
    let mut dy = y.clone();
    let mut dimg = vec![0.0f32; len];
    let mut g = vec![0.0f32; layer.grad_len()];
    layer.backward_ws(&mut dy, &mut dimg, batch, &saves, &tables, &mut sr, &mut si, &mut g);

    let tag = format!("bp-{:?}-d{depth}", field);
    let mut off = 0usize;
    for mi in 0..depth {
        let mask = layer.stack.modules[mi].params.trainable_mask();
        let plen = layer.stack.modules[mi].params.data.len();
        for i in (0..plen).step_by(5) {
            if mask[i] == 0.0 {
                continue;
            }
            let o = layer.stack.modules[mi].params.data[i];
            layer.stack.modules[mi].params.data[i] = o + EPS;
            let lp = loss(&layer, &x);
            layer.stack.modules[mi].params.data[i] = o - EPS;
            let lm = loss(&layer, &x);
            layer.stack.modules[mi].params.data[i] = o;
            check(&format!("{tag} m{mi}[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[off + i]);
        }
        off += plen;
    }
    for i in 0..n {
        let o = layer.bias[i];
        layer.bias[i] = o + EPS;
        let lp = loss(&layer, &x);
        layer.bias[i] = o - EPS;
        let lm = loss(&layer, &x);
        layer.bias[i] = o;
        check(&format!("{tag} bias[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[off + i]);
    }
    // input gradient (dy became dx in place)
    for i in 0..x.len() {
        let o = x[i];
        x[i] = o + EPS;
        let lp = loss(&layer, &x);
        x[i] = o - EPS;
        let lm = loss(&layer, &x);
        x[i] = o;
        check(&format!("{tag} x[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, dy[i]);
    }
}

#[test]
fn butterfly_real_depth1_gradcheck() {
    butterfly_gradcheck(Field::Real, 1, 201);
}

#[test]
fn butterfly_real_depth2_gradcheck() {
    butterfly_gradcheck(Field::Real, 2, 202);
}

#[test]
fn butterfly_complex_depth1_gradcheck() {
    butterfly_gradcheck(Field::Complex, 1, 203);
}

#[test]
fn butterfly_complex_depth2_gradcheck() {
    butterfly_gradcheck(Field::Complex, 2, 204);
}

// ---------------------------------------------------------------------
// circulant (filter, bias, input)
// ---------------------------------------------------------------------

#[test]
fn circulant_ws_gradcheck() {
    let mut rng = Rng::new(105);
    let n = 8;
    let batch = 2;
    let mut layer = CirculantLayer::new(n, &mut rng);
    rng.fill_normal(&mut layer.bias, 0.0, 0.3);
    let mut x = vec![0.0f32; batch * n];
    rng.fill_normal(&mut x, 0.0, 0.7);
    let mut cs: [Vec<f32>; 6] = Default::default();
    for c in cs.iter_mut() {
        c.resize(n, 0.0);
    }

    let loss = |layer: &CirculantLayer, x: &[f32], cs: &mut [Vec<f32>; 6]| -> f64 {
        let mut y = vec![0.0f32; batch * n];
        layer.forward_ws(x, &mut y, batch, None, cs);
        quad_loss(&y)
    };
    let mut y = vec![0.0f32; batch * n];
    let mut xfreq = vec![0.0f32; batch * 2 * n];
    layer.forward_ws(&x, &mut y, batch, Some(&mut xfreq[..]), &mut cs);
    let dy = y.clone();
    let mut dx = vec![0.0f32; batch * n];
    let mut g = vec![0.0f32; layer.grad_len()];
    layer.backward_ws(&xfreq, &dy, &mut dx, &mut g, batch, &mut cs);

    for i in 0..n {
        let o = layer.h[i];
        layer.h[i] = o + EPS;
        let lp = loss(&layer, &x, &mut cs);
        layer.h[i] = o - EPS;
        let lm = loss(&layer, &x, &mut cs);
        layer.h[i] = o;
        check(&format!("circ h[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[i]);
    }
    for i in 0..n {
        let o = layer.bias[i];
        layer.bias[i] = o + EPS;
        let lp = loss(&layer, &x, &mut cs);
        layer.bias[i] = o - EPS;
        let lm = loss(&layer, &x, &mut cs);
        layer.bias[i] = o;
        check(&format!("circ b[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, g[n + i]);
    }
    for i in 0..x.len() {
        let o = x[i];
        x[i] = o + EPS;
        let lp = loss(&layer, &x, &mut cs);
        x[i] = o - EPS;
        let lm = loss(&layer, &x, &mut cs);
        x[i] = o;
        check(&format!("circ x[{i}]"), ((lp - lm) / (2.0 * EPS as f64)) as f32, dx[i]);
    }
}
