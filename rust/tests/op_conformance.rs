//! Conformance suite for the unified `LinearOp` API: every
//! implementation — the eight factory kinds, the forward/inverse FFT
//! pair, the BP-stack adapter, and the dense reference — is checked
//! against its dense matrix from `transforms::matrices` at batch
//! ∈ {1, 3, 64}, plus the concurrency property the workspace
//! externalization must guarantee: one `Arc<dyn LinearOp>` shared by 8
//! threads with private `OpWorkspace`s matches serial results
//! **bit-for-bit**.

use butterfly::butterfly::closed_form::{dct_stack, dft_stack, hadamard_stack};
use butterfly::butterfly::kmatrix::KMatrix;
use butterfly::butterfly::params::Field;
use butterfly::linalg::{CMat, Cpx};
use butterfly::transforms::fuse::{FuseSpec, FuseStrategy};
use butterfly::transforms::matrices::{dft_matrix, idft_matrix, target_matrix};
use butterfly::transforms::op::{ifft_op, plan_with_rng, stack_op, stack_op_fused, LinearOp, OpWorkspace};
use butterfly::transforms::spec::ALL_TRANSFORMS;
use butterfly::util::rng::Rng;
use std::sync::Arc;

/// Batch sizes: degenerate, odd remainder, full serving batch.
const BATCHES: [usize; 3] = [1, 3, 64];

/// Transpose a row-major `[batch, n]` block to column-major `[n, batch]`.
fn to_col(x: &[f32], batch: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; x.len()];
    for b in 0..batch {
        for i in 0..n {
            c[i * batch + b] = x[b * n + i];
        }
    }
    c
}

/// Apply `op` to a row-major batch (via the column-major contract) and
/// compare against the dense reference, both with full complex planes
/// and — for real ops — through the single-plane path.
fn check_against_dense(op: &dyn LinearOp, dense: &CMat, tol: f32, seed: u64) {
    let n = op.n();
    assert_eq!(dense.rows, n, "{}", op.name());
    assert_eq!(op.is_complex(), dense.im.iter().any(|&v| v != 0.0), "{}", op.name());
    let mut ws = OpWorkspace::new();
    let mut rng = Rng::new(seed);
    for batch in BATCHES {
        let mut re = vec![0.0f32; batch * n];
        let mut im = vec![0.0f32; batch * n];
        rng.fill_normal(&mut re, 0.0, 1.0);
        rng.fill_normal(&mut im, 0.0, 1.0);
        let (want_re, want_im) = dense.matvec_batch_planar(&re, &im, batch);
        let mut cre = to_col(&re, batch, n);
        let mut cim = to_col(&im, batch, n);
        op.apply_batch(&mut cre, &mut cim, batch, &mut ws);
        for b in 0..batch {
            for i in 0..n {
                let (gr, gi) = (cre[i * batch + b], cim[i * batch + b]);
                assert!(
                    (gr - want_re[b * n + i]).abs() < tol,
                    "{} B={batch} re ({b},{i}): {gr} vs {}",
                    op.name(),
                    want_re[b * n + i]
                );
                assert!(
                    (gi - want_im[b * n + i]).abs() < tol,
                    "{} B={batch} im ({b},{i}): {gi} vs {}",
                    op.name(),
                    want_im[b * n + i]
                );
            }
        }
        if !op.is_complex() {
            // single-plane path: same real result, no imaginary plane at all
            let mut sre = to_col(&re, batch, n);
            op.apply_batch(&mut sre, &mut [], batch, &mut ws);
            for b in 0..batch {
                for i in 0..n {
                    assert!(
                        (sre[i * batch + b] - want_re[b * n + i]).abs() < tol,
                        "{} B={batch} single-plane ({b},{i})",
                        op.name()
                    );
                }
            }
        }
    }
}

#[test]
fn factory_ops_match_their_dense_targets() {
    let n = 16;
    for kind in ALL_TRANSFORMS {
        // plan_with_rng and target_matrix draw stochastic targets (the
        // convolution filter, the randn entries) with identical rng calls
        let op = plan_with_rng(kind, n, &mut Rng::new(7));
        let dense = target_matrix(kind, n, &mut Rng::new(7));
        check_against_dense(op.as_ref(), &dense, 1e-3, 100 + kind as u64);
    }
}

#[test]
fn fft_inverse_op_matches_idft_matrix() {
    let n = 32;
    check_against_dense(ifft_op(n).as_ref(), &idft_matrix(n), 1e-3, 11);
}

#[test]
fn stack_adapter_matches_closed_form_targets() {
    let n = 32;
    let op = stack_op("bp-dft", &dft_stack(n));
    assert!(op.is_complex());
    assert_eq!(op.name(), "bp-dft");
    check_against_dense(op.as_ref(), &dft_matrix(n), 1e-3, 12);
    // a real stack hardens to a real (single-plane capable) op
    let had = stack_op("bp-hadamard", &hadamard_stack(n));
    assert!(!had.is_complex());
    let dense = target_matrix(butterfly::transforms::spec::TransformKind::Hadamard, n, &mut Rng::new(1));
    check_against_dense(had.as_ref(), &dense, 1e-3, 13);
}

#[test]
fn kmatrix_op_matches_its_dense_reconstruction() {
    // the Block-tied BB* stack through the same adapter: complex and
    // real fields, dense parity at batch {1, 3, 64} (single-plane path
    // included for the real field inside check_against_dense)
    let n = 32;
    for (field, seed) in [(Field::Complex, 14u64), (Field::Real, 15u64)] {
        let mut rng = Rng::new(seed);
        let k = KMatrix::init(n, field, &mut rng);
        let op = stack_op("kmatrix", k.stack());
        assert_eq!(op.is_complex(), field == Field::Complex);
        check_against_dense(op.as_ref(), &k.to_matrix(), 1e-3, seed + 100);
    }
}

/// Every fused variant of a stack (K ∈ {2, 3, 4} × both strategies) must
/// compute the same operator as the unfused stack op and as the stack's
/// dense reconstruction, at batch {1, 3, 64}, including the real
/// single-plane path (inside `check_against_dense`).
///
/// Tolerances are honest about the arithmetic: group-size-1 kernels are
/// bitwise the unfused stage (pinned by `tests/fuse_property.rs`), but a
/// fused group composes its twiddle product in f64 and rounds once to
/// f32 — a *different* (more accurate) f32 association than running the
/// levels separately, so fused-vs-unfused agreement is ~1e-4 on unit-
/// scale data, and both sit inside the suite's 1e-3 dense band.
#[test]
fn fused_ops_match_unfused_stack_and_dense() {
    let n = 32;
    let stacks = [
        ("fft", dft_stack(n)),
        ("dct2", dct_stack(n)), // depth-2 complex stack: perms + 2 modules
        ("fwht", hadamard_stack(n)), // real: exercises the single-plane path
    ];
    let mut ws = OpWorkspace::new();
    for (label, stack) in &stacks {
        let unfused = stack_op(format!("stack-{label}"), stack);
        let dense = stack.to_matrix();
        for k in [2usize, 3, 4] {
            for strategy in [FuseStrategy::Memory, FuseStrategy::Balanced] {
                let spec = FuseSpec::with_k(k, strategy);
                let fused = stack_op_fused(format!("stack-{label}"), stack, &spec);
                assert_eq!(fused.n(), n);
                assert_eq!(fused.is_complex(), unfused.is_complex(), "{label} k={k}");
                check_against_dense(fused.as_ref(), &dense, 1e-3, 40 + k as u64);
                // directly against the unfused apply path, all batches
                let mut rng = Rng::new(50 + k as u64);
                for batch in BATCHES {
                    let mut re = vec![0.0f32; batch * n];
                    let mut im = vec![0.0f32; batch * n];
                    rng.fill_normal(&mut re, 0.0, 1.0);
                    rng.fill_normal(&mut im, 0.0, 1.0);
                    let (mut ure, mut uim) = (re.clone(), im.clone());
                    unfused.apply_batch(&mut ure, &mut uim, batch, &mut ws);
                    fused.apply_batch(&mut re, &mut im, batch, &mut ws);
                    for i in 0..batch * n {
                        assert!(
                            (re[i] - ure[i]).abs() < 1e-4,
                            "{label} k={k} {strategy:?} B={batch} re[{i}]: {} vs {}",
                            re[i],
                            ure[i]
                        );
                        assert!(
                            (im[i] - uim[i]).abs() < 1e-4,
                            "{label} k={k} {strategy:?} B={batch} im[{i}]: {} vs {}",
                            im[i],
                            uim[i]
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn ifft_op_inverts_fft_op() {
    let n = 64;
    let (f, fi) = (plan_with_rng(butterfly::transforms::spec::TransformKind::Dft, n, &mut Rng::new(1)), ifft_op(n));
    let mut ws = OpWorkspace::new();
    let mut rng = Rng::new(2);
    let batch = 3;
    let mut re = vec![0.0f32; batch * n];
    let mut im = vec![0.0f32; batch * n];
    rng.fill_normal(&mut re, 0.0, 1.0);
    rng.fill_normal(&mut im, 0.0, 1.0);
    let (re0, im0) = (re.clone(), im.clone());
    f.apply_batch(&mut re, &mut im, batch, &mut ws);
    fi.apply_batch(&mut re, &mut im, batch, &mut ws);
    for k in 0..batch * n {
        assert!((re[k] - re0[k]).abs() < 1e-4, "re[{k}]");
        assert!((im[k] - im0[k]).abs() < 1e-4, "im[{k}]");
    }
}

#[test]
fn dense_reference_op_round_trips_dft() {
    // dense_op wraps an arbitrary CMat: the unitary DFT as a dense op
    // must agree with the fast FFT op exactly up to fp32 accumulation
    let n = 16;
    let fast = plan_with_rng(butterfly::transforms::spec::TransformKind::Dft, n, &mut Rng::new(1));
    let dense = butterfly::transforms::op::dense_op("dense-dft", dft_matrix(n));
    assert!(dense.is_complex());
    let mut ws = OpWorkspace::new();
    let batch = 3;
    let mut rng = Rng::new(3);
    let mut re = vec![0.0f32; batch * n];
    let mut im = vec![0.0f32; batch * n];
    rng.fill_normal(&mut re, 0.0, 1.0);
    rng.fill_normal(&mut im, 0.0, 1.0);
    let (mut fre, mut fim) = (re.clone(), im.clone());
    fast.apply_batch(&mut fre, &mut fim, batch, &mut ws);
    dense.apply_batch(&mut re, &mut im, batch, &mut ws);
    for k in 0..batch * n {
        assert!((re[k] - fre[k]).abs() < 1e-4, "re[{k}]");
        assert!((im[k] - fim[k]).abs() < 1e-4, "im[{k}]");
    }
}

#[test]
fn one_arc_op_shared_by_8_threads_is_bitwise_serial() {
    // The property the &mut-self/internal-scratch redesign must
    // guarantee: ops hold only immutable tables, all mutation lives in
    // the per-thread OpWorkspace, so 8 threads hammering one
    // Arc<dyn LinearOp> each produce exactly the serial answer.
    let n = 64;
    let batch = 5;
    let ops: Vec<Arc<dyn LinearOp>> = vec![
        plan_with_rng(butterfly::transforms::spec::TransformKind::Dft, n, &mut Rng::new(5)),
        plan_with_rng(butterfly::transforms::spec::TransformKind::Dct, n, &mut Rng::new(5)),
        plan_with_rng(butterfly::transforms::spec::TransformKind::Convolution, n, &mut Rng::new(5)),
        plan_with_rng(butterfly::transforms::spec::TransformKind::Legendre, n, &mut Rng::new(5)),
        stack_op("bp-dft", &dft_stack(n)),
        // fused ops hold only immutable kernel tables and route all
        // scratch through the workspace's fused planes — same proof
        stack_op_fused("fused-dft", &dft_stack(n), &FuseSpec::with_k(3, FuseStrategy::Balanced)),
        stack_op_fused("fused-fwht", &hadamard_stack(n), &FuseSpec::with_k(2, FuseStrategy::Memory)),
        // Block-tied BB* stack (K-matrix): same immutable-tables claim
        stack_op("kmatrix", KMatrix::init(n, Field::Complex, &mut Rng::new(5)).stack()),
        stack_op_fused(
            "fused-kmatrix",
            KMatrix::init(n, Field::Real, &mut Rng::new(5)).stack(),
            &FuseSpec::with_k(2, FuseStrategy::Balanced),
        ),
    ];
    for op in ops {
        let mut rng = Rng::new(6);
        let mut re = vec![0.0f32; batch * n];
        let mut im = vec![0.0f32; batch * n];
        rng.fill_normal(&mut re, 0.0, 1.0);
        rng.fill_normal(&mut im, 0.0, 1.0);
        if !op.is_complex() {
            im.clear(); // exercise the single-plane path concurrently too
        }
        // serial reference
        let (mut want_re, mut want_im) = (re.clone(), im.clone());
        op.apply_batch(&mut want_re, &mut want_im, batch, &mut OpWorkspace::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let op = Arc::clone(&op);
                let (re, im) = (re.clone(), im.clone());
                let (want_re, want_im) = (want_re.clone(), want_im.clone());
                std::thread::spawn(move || {
                    let mut ws = OpWorkspace::new();
                    for _ in 0..25 {
                        let (mut r, mut i) = (re.clone(), im.clone());
                        op.apply_batch(&mut r, &mut i, batch, &mut ws);
                        assert_eq!(r, want_re, "{} re plane diverged across threads", op.name());
                        assert_eq!(i, want_im, "{} im plane diverged across threads", op.name());
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }
}

#[test]
fn ops_are_linear() {
    // L(ax + by) = a L(x) + b L(y): a quick structural check across the
    // whole factory surface, single vectors.
    let n = 16;
    for kind in ALL_TRANSFORMS {
        let op = plan_with_rng(kind, n, &mut Rng::new(9));
        let mut ws = OpWorkspace::new();
        let mut rng = Rng::new(10);
        let mut x = vec![Cpx::ZERO; n];
        let mut y = vec![Cpx::ZERO; n];
        for v in x.iter_mut().chain(y.iter_mut()) {
            *v = Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0));
        }
        let (a, b) = (0.75f32, -1.25f32);
        let apply = |v: &[Cpx], ws: &mut OpWorkspace| -> Vec<Cpx> {
            let mut re: Vec<f32> = v.iter().map(|z| z.re).collect();
            let mut im: Vec<f32> = v.iter().map(|z| z.im).collect();
            op.apply_batch(&mut re, &mut im, 1, ws);
            re.iter().zip(im.iter()).map(|(&r, &i)| Cpx::new(r, i)).collect()
        };
        let lx = apply(&x, &mut ws);
        let ly = apply(&y, &mut ws);
        let mixed: Vec<Cpx> = x
            .iter()
            .zip(y.iter())
            .map(|(&xv, &yv)| xv.scale(a) + yv.scale(b))
            .collect();
        let lmixed = apply(&mixed, &mut ws);
        for i in 0..n {
            let want = lx[i].scale(a) + ly[i].scale(b);
            assert!((lmixed[i] - want).abs() < 1e-3, "{kind} [{i}]");
        }
    }
}
