//! Integration: the batched execution path end to end. The batched
//! hardened apply must agree with (i) the per-item scalar path, (ii) the
//! closed-form dense reference (`CMat::matvec_batch_planar`), (iii) the
//! specialized batched FFT, and (iv) the full serving stack under a
//! batch-forcing load — including non-power-of-2 batch remainders.

use butterfly::butterfly::closed_form::{dft_stack, hadamard_stack};
use butterfly::butterfly::fast::{BatchWorkspace, FastBp, Workspace};
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::fast::FftPlan;
use butterfly::transforms::op::stack_op;
use butterfly::util::rng::Rng;
use std::time::Duration;

/// Batch sizes covering the degenerate, odd-remainder, and serving cases.
const BATCHES: [usize; 3] = [1, 3, 64];

#[test]
fn batched_dft_matches_dense_reference() {
    let n = 32;
    let stack = dft_stack(n);
    let fast = FastBp::from_stack(&stack);
    let dense = stack.to_matrix();
    let mut rng = Rng::new(1);
    for batch in BATCHES {
        let mut re = vec![0.0f32; batch * n];
        let mut im = vec![0.0f32; batch * n];
        rng.fill_normal(&mut re, 0.0, 1.0);
        rng.fill_normal(&mut im, 0.0, 1.0);
        let (want_re, want_im) = dense.matvec_batch_planar(&re, &im, batch);
        let mut ws = BatchWorkspace::new();
        fast.apply_complex_batch(&mut re, &mut im, batch, &mut ws);
        for k in 0..batch * n {
            assert!((re[k] - want_re[k]).abs() < 1e-4, "B={batch} re[{k}]");
            assert!((im[k] - want_im[k]).abs() < 1e-4, "B={batch} im[{k}]");
        }
    }
}

#[test]
fn batched_dft_matches_batched_fft() {
    let n = 64;
    let fast = FastBp::from_stack(&dft_stack(n));
    let plan = FftPlan::new(n);
    let batch = 5;
    let mut rng = Rng::new(2);
    let mut re = vec![0.0f32; batch * n];
    let mut im = vec![0.0f32; batch * n];
    rng.fill_normal(&mut re, 0.0, 1.0);
    rng.fill_normal(&mut im, 0.0, 1.0);
    let (mut fre, mut fim) = (re.clone(), im.clone());
    let mut ws = BatchWorkspace::new();
    fast.apply_complex_batch(&mut re, &mut im, batch, &mut ws);
    // the closed-form stack is the *unitary* DFT; scale the raw FFT
    plan.forward_batch(&mut fre, &mut fim, batch);
    let s = 1.0 / (n as f32).sqrt();
    for k in 0..batch * n {
        assert!((re[k] - fre[k] * s).abs() < 1e-4, "re[{k}]");
        assert!((im[k] - fim[k] * s).abs() < 1e-4, "im[{k}]");
    }
}

#[test]
fn batched_real_hadamard_matches_per_item() {
    let n = 128;
    let fast = FastBp::from_stack(&hadamard_stack(n));
    assert!(!fast.complex);
    let mut rng = Rng::new(3);
    for batch in BATCHES {
        let mut x = vec![0.0f32; batch * n];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let before = x.clone();
        let mut bws = BatchWorkspace::with_capacity(batch, n);
        fast.apply_real_batch(&mut x, batch, &mut bws);
        let mut ws = Workspace::new(n);
        for bi in 0..batch {
            let mut row = before[bi * n..(bi + 1) * n].to_vec();
            fast.apply_real(&mut row, &mut ws);
            for i in 0..n {
                assert!((row[i] - x[bi * n + i]).abs() < 1e-6, "B={batch} row {bi} [{i}]");
            }
        }
    }
}

#[test]
fn serving_stack_batches_and_answers_correctly() {
    // Force real coalesced batches: 16 concurrent clients, a generous
    // window, and max_batch below the client count so at least one
    // drained batch has a non-power-of-2 size.
    let n = 16;
    let svc_cfg = BatcherConfig { max_batch: 6, max_wait: Duration::from_millis(20), queue_cap: 256 };
    let mut router = Router::new();
    router.install("dft", stack_op("dft", &dft_stack(n)), 1, svc_cfg);
    let f = butterfly::transforms::matrices::dft_matrix(n);
    let handles: Vec<_> = (0..16)
        .map(|k| {
            let h = router.handle("dft").unwrap();
            std::thread::spawn(move || {
                let mut x = vec![0.0f32; 16];
                x[k] = 1.0;
                let (re, im) = h.call(x, vec![0.0; 16]).unwrap();
                (k, re, im)
            })
        })
        .collect();
    for h in handles {
        let (k, re, im) = h.join().unwrap();
        for i in 0..n {
            assert!((re[i] - f.re[i * n + k]).abs() < 1e-4, "col {k} re[{i}]");
            assert!((im[i] - f.im[i * n + k]).abs() < 1e-4, "col {k} im[{i}]");
        }
    }
    let stats = router.shutdown();
    let s = &stats["dft"];
    assert_eq!(s.served, 16);
    eprintln!("served {} requests in {} batches (mean batch {:.2})", s.served, s.batches, s.mean_batch);
}
