//! The compression pipeline end to end, plus the property/determinism
//! suites the ISSUE-5 satellites specify:
//!
//! - property: exported `LinearOp` ≡ `ButterflyLayer` forward (minus
//!   bias) at batch {1, 3, 64}; linearity of the exported op; artifact
//!   pack → save → load → apply round-trip is **bitwise**;
//! - determinism: `train_mlp` with one seed yields an identical
//!   `TrainReport` for `T ∈ {1, 2, 8}`, and the engine at `T = 1` with
//!   one chunk per batch reproduces the legacy `train_step` loop
//!   bit-for-bit;
//! - regression: evaluation (`&self`) can never perturb training state;
//! - end to end: a butterfly-hidden MLP trained on the multiband
//!   Table-1 task beats the parameter-matched low-rank baseline, its
//!   exported op passes `op_conformance`-style dense-reference parity,
//!   and the op serves through a `ServicePool`.

use butterfly::butterfly::params::Field;
use butterfly::data::batcher::BatchIter;
use butterfly::data::synth::{downsample, generate, DatasetKind};
use butterfly::nn::mlp::{train_mlp, train_mlp_model, TrainConfig};
use butterfly::nn::{ButterflyLayer, CirculantLayer, CompressMlp, HiddenKind, Layer, MlpTrainer, NnWorkspace};
use butterfly::runtime::engine::unpack_stack;
use butterfly::serving::{BatcherConfig, ServicePool};
use butterfly::transforms::op::{LinearOp, OpWorkspace};
use butterfly::util::quickcheck::{check_close, run_prop, PropConfig};
use butterfly::util::rng::Rng;

/// Row-major `[b, n]` → column-major `[n, b]`.
fn to_cols(x: &[f32], batch: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; batch * n];
    for b in 0..batch {
        for i in 0..n {
            c[i * batch + b] = x[b * n + i];
        }
    }
    c
}

// ---------------------------------------------------------------------
// property: exported op ≡ layer forward (minus bias)
// ---------------------------------------------------------------------

#[test]
fn prop_exported_op_matches_layer_forward() {
    let cfg = PropConfig { cases: 24, ..Default::default() };
    run_prop("export ≡ forward − bias", &cfg, |g| {
        let n = g.pow2(3, 5); // 8..32
        let depth = *g.choose(&[1usize, 2]);
        let field = if g.bool() { Field::Complex } else { Field::Real };
        let batch = *g.choose(&[1usize, 3, 64]);
        let mut rng = Rng::new(g.rng.next_u64());
        let mut layer = ButterflyLayer::new(n, depth, field, &mut rng);
        rng.fill_normal(&mut layer.bias, 0.0, 0.5);
        let x = g.vec_normal(batch * n);
        // layer forward (legacy eval path)
        let mut lyr = layer.forward(&x, batch, false);
        for bi in 0..batch {
            for i in 0..n {
                lyr[bi * n + i] -= layer.bias[i];
            }
        }
        // exported op on column-major planes
        let op = layer.export_op("prop");
        let mut re = to_cols(&x, batch, n);
        let mut im = vec![0.0f32; batch * n];
        let mut ws = OpWorkspace::new();
        op.apply_batch(&mut re, &mut im, batch, &mut ws);
        let want = to_cols(&lyr, batch, n);
        check_close(&re, &want, 1e-5, 1e-4)
    });
}

#[test]
fn prop_exported_op_is_linear() {
    let cfg = PropConfig { cases: 24, ..Default::default() };
    run_prop("export linearity", &cfg, |g| {
        let n = g.pow2(3, 5);
        let field = if g.bool() { Field::Complex } else { Field::Real };
        let mut rng = Rng::new(g.rng.next_u64());
        let layer = ButterflyLayer::new(n, 2, field, &mut rng);
        let op = layer.export_op("lin");
        let a = 0.5 + g.f32_in(1.0).abs();
        let x = g.vec_normal(n);
        let y = g.vec_normal(n);
        let mut ws = OpWorkspace::new();
        let apply = |v: &[f32], ws: &mut OpWorkspace| -> (Vec<f32>, Vec<f32>) {
            let mut re = v.to_vec();
            let mut im = vec![0.0f32; n];
            op.apply_batch(&mut re, &mut im, 1, ws);
            (re, im)
        };
        // op(a·x + y)
        let mixed: Vec<f32> = x.iter().zip(&y).map(|(&u, &v)| a * u + v).collect();
        let (sre, sim) = apply(&mixed, &mut ws);
        // a·op(x) + op(y)
        let (xre, xim) = apply(&x, &mut ws);
        let (yre, yim) = apply(&y, &mut ws);
        let wre: Vec<f32> = xre.iter().zip(&yre).map(|(&u, &v)| a * u + v).collect();
        let wim: Vec<f32> = xim.iter().zip(&yim).map(|(&u, &v)| a * u + v).collect();
        check_close(&sre, &wre, 1e-4, 1e-3)?;
        check_close(&sim, &wim, 1e-4, 1e-3)
    });
}

#[test]
fn prop_artifact_roundtrip_is_bitwise() {
    let dir = std::env::temp_dir();
    let cfg = PropConfig { cases: 12, ..Default::default() };
    let mut case = 0usize;
    run_prop("artifact round-trip", &cfg, |g| {
        case += 1;
        let n = g.pow2(3, 5);
        let mut rng = Rng::new(g.rng.next_u64());
        let batch = *g.choose(&[1usize, 3, 64]);
        let x = g.vec_normal(batch * n);
        // alternate butterfly / circulant artifacts
        let (direct, art) = if g.bool() {
            let field = if g.bool() { Field::Complex } else { Field::Real };
            let mut layer = ButterflyLayer::new(n, 2, field, &mut rng);
            rng.fill_normal(&mut layer.bias, 0.0, 0.5);
            (layer.export_op("rt"), layer.export_artifact("rt"))
        } else {
            let layer = CirculantLayer::new(n, &mut rng);
            (layer.export_op(), layer.export_artifact("rt"))
        };
        // pid-unique names: two concurrent runs of this suite (debug +
        // release, or two checkouts sharing /tmp) must not race
        let path = dir.join(format!("butterfly-layer-rt-{}-{case}.json", std::process::id()));
        art.save(&path).map_err(|e| e.to_string())?;
        let loaded = butterfly::runtime::artifacts::LayerArtifact::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        if loaded != art {
            return Err("artifact changed across save/load".into());
        }
        let rebuilt = loaded.to_op().map_err(|e| e.to_string())?;
        if rebuilt.is_complex() != direct.is_complex() || rebuilt.n() != direct.n() {
            return Err("rebuilt op metadata differs".into());
        }
        let mut ws = OpWorkspace::new();
        let mut re_a = to_cols(&x, batch, n);
        let mut re_b = re_a.clone();
        let (mut im_a, mut im_b) = if direct.is_complex() {
            (vec![0.0f32; batch * n], vec![0.0f32; batch * n])
        } else {
            (Vec::new(), Vec::new())
        };
        direct.apply_batch(&mut re_a, &mut im_a, batch, &mut ws);
        rebuilt.apply_batch(&mut re_b, &mut im_b, batch, &mut ws);
        for (i, (a, b)) in re_a.iter().zip(&re_b).chain(im_a.iter().zip(&im_b)).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("round-trip not bitwise at {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// determinism
// ---------------------------------------------------------------------

fn small_task() -> (butterfly::data::batcher::Dataset, butterfly::data::batcher::Dataset) {
    let train = downsample(&generate(DatasetKind::CifarGray, 120, 5), 64);
    let test = downsample(&generate(DatasetKind::CifarGray, 40, 6), 64);
    (train, test)
}

#[test]
fn train_report_is_identical_across_thread_counts() {
    let (train, test) = small_task();
    for kind in [HiddenKind::BpbpReal, HiddenKind::Circulant, HiddenKind::LowRank { rank: 4 }] {
        let mut reports = Vec::new();
        for threads in [1usize, 2, 8] {
            let cfg = TrainConfig { epochs: 2, batch: 20, lr: 0.02, threads, chunk: 8, ..Default::default() };
            reports.push(train_mlp(kind, &train, &test, &cfg));
        }
        assert_eq!(reports[0], reports[1], "{}: T=1 vs T=2", kind.name());
        assert_eq!(reports[0], reports[2], "{}: T=1 vs T=8", kind.name());
    }
}

#[test]
fn engine_t1_single_chunk_matches_legacy_loop_bitwise() {
    let (train, test) = small_task();
    let kind = HiddenKind::BpbpReal;
    let cfg = TrainConfig { epochs: 2, batch: 20, lr: 0.02, threads: 1, chunk: 20, ..Default::default() };
    let engine_report = train_mlp(kind, &train, &test, &cfg);

    // replicate train_mlp by hand on the legacy &mut train_step path:
    // identical rng stream, split, batching, and evaluation
    let mut rng = Rng::new(cfg.seed);
    let split = train.split(cfg.val_frac);
    let mut model = CompressMlp::new(kind, train.dim, train.classes, &mut rng);
    let mut ws = NnWorkspace::new();
    for epoch in 0..cfg.epochs {
        let mut iter = BatchIter::new(&split.train, cfg.batch, &mut rng);
        let mut total = 0.0f64;
        let mut batches = 0usize;
        while let Some((x, y)) = iter.next_batch() {
            let (loss, _) = model.train_step(&x, &y, cfg.lr, cfg.momentum, cfg.weight_decay);
            total += loss as f64;
            batches += 1;
        }
        let legacy_loss = (total / batches as f64) as f32;
        let got = engine_report.epochs[epoch].train_loss;
        assert_eq!(legacy_loss.to_bits(), got.to_bits(), "epoch {epoch} loss: {legacy_loss} vs {got}");
        let legacy_val = model.evaluate(&split.holdout, cfg.batch, &mut ws);
        assert_eq!(legacy_val, engine_report.epochs[epoch].val_acc, "epoch {epoch} val acc");
    }
}

#[test]
fn evaluation_never_perturbs_training() {
    let (train, _) = small_task();
    let probe = downsample(&generate(DatasetKind::CifarGray, 30, 7), 64);
    let mk = || CompressMlp::new(HiddenKind::BpbpReal, 64, 10, &mut Rng::new(11));
    let mut plain = mk();
    let mut evaluated = mk();
    let mut trainer_a = MlpTrainer::new(2, 8);
    let mut trainer_b = MlpTrainer::new(2, 8);
    let mut ws = NnWorkspace::new();
    let x = &train.x[..20 * 64];
    let y = &train.y[..20];
    for _ in 0..4 {
        let (la, _) = trainer_a.step(&mut plain, x, y, 0.02, 0.9, 0.0);
        // interleave evaluations on the other model — must change nothing
        let _ = evaluated.evaluate(&probe, 7, &mut ws);
        let (lb, _) = trainer_b.step(&mut evaluated, x, y, 0.02, 0.9, 0.0);
        let _ = evaluated.evaluate(&probe, 30, &mut ws);
        assert_eq!(la.to_bits(), lb.to_bits(), "losses diverged after an eval");
    }
    let la = plain.logits_ws(x, 20, &mut ws).to_vec();
    let lb = evaluated.logits_ws(x, 20, &mut ws).to_vec();
    assert_eq!(la, lb, "evaluation perturbed training state");
}

// ---------------------------------------------------------------------
// end to end: the §4.2 compression claim + serving
// ---------------------------------------------------------------------

#[test]
fn compress_end_to_end_beats_matched_lowrank_and_serves() {
    let dim = 256;
    let train = downsample(&generate(DatasetKind::Multiband, 400, 42), dim);
    let test = downsample(&generate(DatasetKind::Multiband, 200, 43), dim);
    let cfg = TrainConfig { epochs: 12, batch: 25, lr: 0.03, threads: 2, chunk: 8, ..Default::default() };

    let rank = HiddenKind::parameter_matched_rank(dim);
    let (bp_report, bp_model) = train_mlp_model(HiddenKind::BpbpReal, &train, &test, &cfg);
    let lr_report = train_mlp(HiddenKind::LowRank { rank }, &train, &test, &cfg);

    // parameter parity (the fixed-budget comparison is fair)
    let hi = bp_report.hidden_params.max(lr_report.hidden_params) as f64;
    let lo = bp_report.hidden_params.min(lr_report.hidden_params) as f64;
    assert!(hi / lo < 1.05, "budgets differ: bp {} vs low-rank {}", bp_report.hidden_params, lr_report.hidden_params);

    // §4.2's claim at fixed budget: butterfly structure wins on a task
    // whose signal spans many frequency channels
    assert!(
        bp_report.test_acc > lr_report.test_acc,
        "butterfly {:.3} must beat parameter-matched low-rank-{rank} {:.3}",
        bp_report.test_acc,
        lr_report.test_acc
    );
    assert!(bp_report.test_acc > 0.3, "butterfly acc {:.3} too weak to mean anything", bp_report.test_acc);

    // export: op ≡ dense reconstruction of the trained stack
    // (op_conformance-style dense-reference parity at batch {1, 3, 64})
    let op = bp_model.export_hidden_op();
    assert!(!op.is_complex(), "real-field export must be a real op");
    assert_eq!(op.n(), dim);
    let art = bp_model.export_hidden_artifact("e2e").expect("butterfly artifact");
    let dense = unpack_stack(dim, art.depth, &art.theta).to_matrix();
    let mut ws = OpWorkspace::new();
    let mut rng = Rng::new(99);
    for batch in [1usize, 3, 64] {
        let mut x = vec![0.0f32; batch * dim];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut re = x.clone();
        op.apply_batch(&mut re, &mut [], batch, &mut ws);
        // matvec_batch_planar is row-major [batch, n]
        let mut rows = vec![0.0f32; batch * dim];
        for b in 0..batch {
            for i in 0..dim {
                rows[b * dim + i] = x[i * batch + b];
            }
        }
        let zeros = vec![0.0f32; batch * dim];
        let (want_re, _) = dense.matvec_batch_planar(&rows, &zeros, batch);
        for b in 0..batch {
            for i in 0..dim {
                let got = re[i * batch + b];
                let want = want_re[b * dim + i];
                assert!(
                    (got - want).abs() < 1e-3 + 1e-3 * want.abs(),
                    "B={batch} [{i},{b}]: {got} vs {want}"
                );
            }
        }
    }

    // serve the compressed layer through a worker pool and check the
    // answers against the dense reconstruction
    let svc = ServicePool::spawn("compressed", op, 2, BatcherConfig::default());
    let h = svc.handle();
    assert!(!h.is_complex());
    let clients: Vec<_> = (0..8)
        .map(|k| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(500 + k);
                let mut x = vec![0.0f32; dim];
                rng.fill_normal(&mut x, 0.0, 1.0);
                (x.clone(), h.call_real(x).unwrap())
            })
        })
        .collect();
    let zeros = vec![0.0f32; dim];
    for c in clients {
        let (x, got) = c.join().unwrap();
        let (want, _) = dense.matvec_batch_planar(&x, &zeros, 1);
        for i in 0..dim {
            assert!((got[i] - want[i]).abs() < 1e-3 + 1e-3 * want[i].abs(), "serve [{i}]");
        }
    }
    let stats = svc.shutdown();
    assert_eq!(stats.served, 8);
    assert_eq!(stats.bad_request, 0);
}

// ---------------------------------------------------------------------
// column-major serving layout helper is itself exercised above; pin the
// low-rank export path too (flops story for the CLI table)
// ---------------------------------------------------------------------

#[test]
fn lowrank_export_is_fast_form() {
    let mut rng = Rng::new(21);
    let model = CompressMlp::new(HiddenKind::LowRank { rank: 4 }, 64, 10, &mut rng);
    let op = model.export_hidden_op();
    assert_eq!(op.flops_per_apply(), 4 * 64 * 4, "low-rank op must be O(n·r), not O(n²)");
    let dense = CompressMlp::new(HiddenKind::Dense, 64, 10, &mut rng).export_hidden_op();
    assert!(op.flops_per_apply() < dense.flops_per_apply() / 4);
}
