//! The bench-gate contract: noise-band math, missing/new-scenario
//! handling, env-fingerprint mismatch downgrading failure to a warning,
//! and the `BENCH_*.json` round-trip through `util::json`.
//!
//! These tests pin the behavior CI leans on — in particular that an
//! injected synthetic regression makes the gate exit nonzero (the
//! acceptance criterion for the harness) and that baselines from a
//! different machine can never fail someone else's build.

use butterfly::runtime::bench::{
    gate_exit_code, Comparison, EnvFingerprint, Report, Scenario, Stats, Unit, Verdict,
    DEFAULT_NOISE_BAND, SMOKE_NOISE_BAND,
};

fn env(cpu: &str, smoke: bool) -> EnvFingerprint {
    EnvFingerprint {
        cpu: cpu.to_string(),
        cores: 8,
        rustc: "rustc 1.75.0".to_string(),
        git_sha: "abc123def456".to_string(),
        flags: "release".to_string(),
        smoke,
        provenance: "measured".to_string(),
        isa: "avx2,fma".to_string(),
        kernels: "avx2".to_string(),
    }
}

fn scenario(id: &str, unit: Unit, median: f64) -> Scenario {
    Scenario {
        id: id.to_string(),
        unit,
        stats: Stats { median, q1: median * 0.98, q3: median * 1.02, reps: 5 },
        noise_band: DEFAULT_NOISE_BAND,
    }
}

fn report(area: &str, env: EnvFingerprint, scenarios: Vec<Scenario>) -> Report {
    Report { area: area.to_string(), env, scenarios }
}

fn row<'a>(cmp: &'a Comparison, id: &str) -> &'a butterfly::runtime::bench::CompareRow {
    cmp.rows.iter().find(|r| r.id == id).unwrap_or_else(|| panic!("no row '{id}'"))
}

// ---------------------------------------------------------------------------
// noise-band math
// ---------------------------------------------------------------------------

#[test]
fn within_band_is_ok_in_both_directions() {
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    for median in [870.0, 1000.0, 1140.0] {
        let cur = report(
            "ops",
            env("cpu-a", false),
            vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, median)],
        );
        let cmp = Comparison::compare(&base, &cur);
        assert_eq!(row(&cmp, "ops/dft/n1024/B1").verdict, Verdict::Ok, "median {median}");
        assert!(cmp.gate());
        assert_eq!(gate_exit_code(&[cmp]), 0);
    }
}

#[test]
fn injected_regression_fails_the_gate_lower_is_better() {
    // ns/vec regresses UPWARD: +20% latency is out of the ±15% band
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1200.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    let r = row(&cmp, "ops/dft/n1024/B1");
    assert_eq!(r.verdict, Verdict::Regressed);
    assert!((r.ratio.unwrap() - 1.2).abs() < 1e-9);
    assert!(!cmp.gate());
    assert_eq!(gate_exit_code(&[cmp]), 1, "the CI gate must exit nonzero on a regression");
}

#[test]
fn injected_regression_fails_the_gate_higher_is_better() {
    // steps/sec regresses DOWNWARD: −20% throughput is out of band,
    // while +20% is an improvement, not a regression
    let base = report(
        "train",
        env("cpu-a", false),
        vec![scenario("train/recovery-dft/n256/T1", Unit::StepsPerSec, 500.0)],
    );
    let slower = report(
        "train",
        env("cpu-a", false),
        vec![scenario("train/recovery-dft/n256/T1", Unit::StepsPerSec, 400.0)],
    );
    let cmp = Comparison::compare(&base, &slower);
    assert_eq!(row(&cmp, "train/recovery-dft/n256/T1").verdict, Verdict::Regressed);
    assert_eq!(gate_exit_code(&[cmp]), 1);

    let faster = report(
        "train",
        env("cpu-a", false),
        vec![scenario("train/recovery-dft/n256/T1", Unit::StepsPerSec, 600.0)],
    );
    let cmp = Comparison::compare(&base, &faster);
    assert_eq!(row(&cmp, "train/recovery-dft/n256/T1").verdict, Verdict::Improved);
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

#[test]
fn per_entry_noise_band_overrides_the_default() {
    // a committed baseline can widen its own band: ±50% tolerates a
    // +40% latency swing that the default band would fail
    let mut wide = scenario("ops/randn/n256/B1", Unit::NsPerVec, 1000.0);
    wide.noise_band = 0.50;
    let base = report("ops", env("cpu-a", false), vec![wide]);
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/randn/n256/B1", Unit::NsPerVec, 1400.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    let r = row(&cmp, "ops/randn/n256/B1");
    assert_eq!(r.verdict, Verdict::Ok);
    assert!((r.band - 0.50).abs() < 1e-12, "band comes from the baseline entry");
}

#[test]
fn smoke_runs_widen_the_band_to_at_least_35_percent() {
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    // +25% would regress under the full ±15% band, but the current run
    // is smoke (1 rep), so the effective band is ±35%
    let cur = report(
        "ops",
        env("cpu-a", true),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1250.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    let r = row(&cmp, "ops/dft/n1024/B1");
    assert!((r.band - SMOKE_NOISE_BAND).abs() < 1e-12);
    assert_eq!(r.verdict, Verdict::Ok);
    // ... and a gross +50% regression still fails even at smoke width
    let cur = report(
        "ops",
        env("cpu-a", true),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1500.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert_eq!(row(&cmp, "ops/dft/n1024/B1").verdict, Verdict::Regressed);
    assert_eq!(gate_exit_code(&[cmp]), 1);
}

// ---------------------------------------------------------------------------
// missing / new scenarios
// ---------------------------------------------------------------------------

#[test]
fn missing_and_new_scenarios_warn_but_never_fail() {
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![
            scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0),
            scenario("ops/retired/n1024/B1", Unit::NsPerVec, 500.0),
        ],
    );
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![
            scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1010.0),
            scenario("ops/brand-new/n1024/B1", Unit::NsPerVec, 700.0),
        ],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert_eq!(row(&cmp, "ops/retired/n1024/B1").verdict, Verdict::Missing);
    assert_eq!(row(&cmp, "ops/brand-new/n1024/B1").verdict, Verdict::New);
    assert_eq!(row(&cmp, "ops/dft/n1024/B1").verdict, Verdict::Ok);
    assert!(cmp.gate(), "missing/new entries must not fail the gate");
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

#[test]
fn degenerate_medians_are_incomparable_not_regressions() {
    // a zero / non-finite median means the measurement is broken, not
    // that perf regressed — report it as New (no ratio), don't gate
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 0.0)],
    );
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    let r = row(&cmp, "ops/dft/n1024/B1");
    assert_eq!(r.verdict, Verdict::New);
    assert!(r.ratio.is_none());
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

// ---------------------------------------------------------------------------
// env-fingerprint mismatch downgrades failure to a warning
// ---------------------------------------------------------------------------

#[test]
fn cross_machine_regression_is_advisory_only() {
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    // 3x slower — but measured on different hardware
    let cur = report(
        "ops",
        env("cpu-b", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 3000.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert!(!cmp.env_match);
    // the regression is still REPORTED in the table...
    assert_eq!(row(&cmp, "ops/dft/n1024/B1").verdict, Verdict::Regressed);
    assert_eq!(cmp.regressions(), 1);
    // ...but the gate passes: cross-machine numbers are context
    assert!(cmp.gate());
    assert_eq!(gate_exit_code(&[cmp]), 0);
    assert!(cmp.render().contains("advisory"), "render must say why it passed:\n{}", cmp.render());
}

#[test]
fn kernel_backend_mismatch_is_advisory_only() {
    // same machine, but the baseline was measured with AVX2 kernels and
    // the current run is pinned to scalar — numbers aren't comparable
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let mut cur_env = env("cpu-a", false);
    cur_env.kernels = "scalar".to_string();
    let cur = report(
        "ops",
        cur_env,
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 3000.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert!(!cmp.env_match);
    assert_eq!(row(&cmp, "ops/dft/n1024/B1").verdict, Verdict::Regressed);
    assert!(cmp.gate(), "backend mismatch must not hard-fail the gate");
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

#[test]
fn pre_kernel_layer_baselines_never_hard_gate() {
    // baselines committed before the kernel layer existed have no
    // "kernels" field; they deserialize as "" and can only be advisory
    let mut old_env = env("cpu-a", false);
    old_env.isa = String::new();
    old_env.kernels = String::new();
    let base = report(
        "ops",
        old_env,
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 5000.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert!(!cmp.env_match);
    assert!(cmp.gate());
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

#[test]
fn estimated_baselines_never_hard_gate() {
    // committed seeds carry provenance:"estimated" until re-baselined on
    // the real runner class — they must not be able to fail a build
    let mut base_env = env("cpu-a", false);
    base_env.provenance = "estimated".to_string();
    let base = report(
        "ops",
        base_env,
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let cur = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 5000.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert!(!cmp.env_match);
    assert!(cmp.gate());
    assert_eq!(gate_exit_code(&[cmp]), 0);
}

#[test]
fn mismatch_only_downgrades_it_does_not_hide_passes() {
    // env mismatch with NO regressions is still a plain pass
    let base = report(
        "ops",
        env("cpu-a", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
    );
    let cur = report(
        "ops",
        env("cpu-b", false),
        vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1010.0)],
    );
    let cmp = Comparison::compare(&base, &cur);
    assert!(cmp.gate());
    assert_eq!(cmp.regressions(), 0);
}

#[test]
fn gate_exit_code_aggregates_across_areas() {
    let mk = |median: f64| {
        let base = report(
            "ops",
            env("cpu-a", false),
            vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, 1000.0)],
        );
        let cur = report(
            "ops",
            env("cpu-a", false),
            vec![scenario("ops/dft/n1024/B1", Unit::NsPerVec, median)],
        );
        Comparison::compare(&base, &cur)
    };
    assert_eq!(gate_exit_code(&[]), 0, "no baselines at all is a pass");
    assert_eq!(gate_exit_code(&[mk(1000.0), mk(1010.0)]), 0);
    // one bad area fails the whole gate
    assert_eq!(gate_exit_code(&[mk(1000.0), mk(2000.0)]), 1);
}

// ---------------------------------------------------------------------------
// JSON round-trip through util::json
// ---------------------------------------------------------------------------

#[test]
fn report_round_trips_through_json_text() {
    let rep = report(
        "serving",
        env("Example CPU @ 3.2GHz", false),
        vec![
            scenario("serving/pool-dft/n1024/W1", Unit::VectorsPerSec, 41235.5),
            {
                let mut s = scenario("serving/pool-dft/n1024/W8", Unit::VectorsPerSec, 198000.0);
                s.noise_band = 0.25;
                s
            },
        ],
    );
    let text = rep.to_json().to_string_pretty();
    let parsed = butterfly::util::json::parse(&text).expect("valid JSON");
    let back = Report::from_json(&parsed).expect("well-formed report");
    assert_eq!(back, rep);
    // schema version is stamped in the serialized form
    assert_eq!(parsed.get("schema").and_then(|v| v.as_usize()), Some(1));
}

#[test]
fn report_save_load_round_trips_on_disk() {
    let rep = report(
        "train",
        env("Example CPU @ 3.2GHz", true),
        vec![scenario("train/recovery-dft/n256/T2", Unit::StepsPerSec, 812.25)],
    );
    let path = std::env::temp_dir().join(format!("bench_compare_rt_{}.json", std::process::id()));
    rep.save(&path).expect("save");
    let back = Report::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(back, rep);
}

#[test]
fn loading_rejects_malformed_reports() {
    assert!(butterfly::util::json::parse("{").is_err());
    let missing_env = butterfly::util::json::parse(r#"{"area": "ops", "scenarios": []}"#).unwrap();
    assert!(Report::from_json(&missing_env).is_err());
    let bad_unit = butterfly::util::json::parse(
        r#"{"area":"ops","env":{"cpu":"x","cores":1,"rustc":"r","git_sha":"s","flags":"release","smoke":false},
            "scenarios":[{"id":"a","unit":"parsecs","median":1,"q1":1,"q3":1,"reps":1}]}"#,
    )
    .unwrap();
    assert!(Report::from_json(&bad_unit).is_err());
    // absent noise_band falls back to the default
    let no_band = butterfly::util::json::parse(
        r#"{"area":"ops","env":{"cpu":"x","cores":1,"rustc":"r","git_sha":"s","flags":"release","smoke":false},
            "scenarios":[{"id":"a","unit":"ns_per_vec","median":1,"q1":1,"q3":1,"reps":1}]}"#,
    )
    .unwrap();
    let rep = Report::from_json(&no_band).expect("noise_band is optional");
    assert!((rep.scenarios[0].noise_band - DEFAULT_NOISE_BAND).abs() < 1e-12);
    assert_eq!(rep.env.provenance, "measured", "absent provenance defaults to measured");
}
