//! Integration: the AOT bridge. Loads the HLO text produced by
//! `python/compile/aot.py`, compiles it on the PJRT CPU client, executes
//! it, and checks parity against the pure-Rust native engine — proving
//! the L1 (Pallas) + L2 (JAX) + L3 (Rust) layers compose.
//!
//! Skips gracefully (with a loud message) when `artifacts/` has not been
//! built; `make test` always builds it first.

use butterfly::butterfly::params::InitScheme;
use butterfly::butterfly::params::{BpParams, Field, PermTying, TwiddleTying};
use butterfly::runtime::engine::{theta_len, Engine, NativeEngine, XlaEngine};
use butterfly::runtime::tensor::Tensor;
use butterfly::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn random_theta(n: usize, depth: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..depth {
        let mut p = BpParams::init(
            n,
            Field::Complex,
            TwiddleTying::Factor,
            PermTying::Untied,
            InitScheme::OrthogonalLike,
            &mut rng,
        );
        for k in 0..p.levels {
            for g in 0..3 {
                p.set_logit(k, g, rng.normal_f32(0.0, 1.0));
            }
        }
        out.extend_from_slice(&p.data);
    }
    out
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

#[test]
fn xla_bp_apply_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::open(&dir).expect("open artifacts");
    let mut native = NativeEngine::new();
    for (n, depth) in [(8usize, 1usize), (16, 1), (64, 1), (16, 2)] {
        let entry = format!("bp_apply_n{n}_d{depth}");
        if !xla.has_entry(&entry) {
            continue;
        }
        let batch = 16; // APPLY_BATCH in aot.py
        let theta = random_theta(n, depth, 42 + n as u64);
        let mut rng = Rng::new(7);
        let mut x = vec![0.0f32; 2 * batch * n];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let inputs =
            [Tensor::new(vec![theta_len(n, depth)], theta), Tensor::new(vec![2, batch, n], x)];
        let got = xla.run(&entry, &inputs).expect("xla run");
        let want = native.run(&entry, &inputs).expect("native run");
        assert_close(&got[0].data, &want[0].data, 1e-3, &entry);
    }
}

#[test]
fn xla_factorize_step_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::open(&dir).expect("open artifacts");
    let mut native = NativeEngine::new();
    let (n, depth) = (8usize, 1usize);
    let entry = format!("factorize_step_n{n}_d{depth}");
    let p = theta_len(n, depth);
    let theta = random_theta(n, depth, 5);
    let target = butterfly::transforms::matrices::dft_matrix(n);
    let mut tdata = target.re.clone();
    tdata.extend_from_slice(&target.im);
    let inputs = [
        Tensor::new(vec![p], theta),
        Tensor::zeros(vec![p]),
        Tensor::zeros(vec![p]),
        Tensor::new(vec![1], vec![0.0]),
        Tensor::new(vec![1], vec![0.02]),
        Tensor::new(vec![2, n, n], tdata),
    ];
    let got = xla.run(&entry, &inputs).expect("xla run");
    let want = native.run(&entry, &inputs).expect("native run");
    // loss identical-ish; parameters: same update direction & magnitude
    assert_close(&got[3].data, &want[3].data, 1e-4, "loss");
    assert_close(&got[0].data, &want[0].data, 5e-3, "theta'");
    assert_close(&got[1].data, &want[1].data, 5e-3, "m'");
}

#[test]
fn xla_factorize_loop_reaches_low_rmse() {
    // drive a short training loop ENTIRELY through the XLA engine — the
    // production configuration (python never in the loop).
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::open(&dir).expect("open artifacts");
    let (n, depth) = (8usize, 1usize);
    let entry = format!("factorize_step_n{n}_d{depth}");
    let p = theta_len(n, depth);
    let target = butterfly::transforms::matrices::dft_matrix(n);
    let mut tdata = target.re.clone();
    tdata.extend_from_slice(&target.im);
    let ttensor = Tensor::new(vec![2, n, n], tdata);
    let mut theta = Tensor::new(vec![p], random_theta(n, depth, 11));
    let mut m = Tensor::zeros(vec![p]);
    let mut v = Tensor::zeros(vec![p]);
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..150 {
        let out = xla
            .run(
                &entry,
                &[
                    theta.clone(),
                    m.clone(),
                    v.clone(),
                    Tensor::new(vec![1], vec![step as f32]),
                    Tensor::new(vec![1], vec![0.05]),
                    ttensor.clone(),
                ],
            )
            .expect("xla step");
        if step == 0 {
            first = out[3].data[0];
        }
        last = out[3].data[0];
        theta = out[0].clone();
        m = out[1].clone();
        v = out[2].clone();
    }
    assert!(last < first * 0.2, "loss {first} → {last}");
}

#[test]
fn manifest_is_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let m = butterfly::runtime::artifacts::Manifest::load(&dir).unwrap();
    assert!(m.complete(), "manifest references missing HLO files");
    assert!(m.entries.len() >= 10);
    let xla = XlaEngine::open(&dir).unwrap();
    for name in m.entries.keys().take(3) {
        assert!(xla.has_entry(name));
    }
}

#[test]
fn xla_bp_apply_matches_native_n1024() {
    let Some(dir) = artifacts_dir() else { return };
    let mut xla = XlaEngine::open(&dir).expect("open artifacts");
    let mut native = NativeEngine::new();
    let (n, depth) = (1024usize, 1usize);
    let entry = "bp_apply_n1024_d1";
    if !xla.has_entry(entry) {
        return;
    }
    let batch = 16;
    let theta = random_theta(n, depth, 9);
    let mut rng = Rng::new(3);
    let mut x = vec![0.0f32; 2 * batch * n];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let inputs = [Tensor::new(vec![theta_len(n, depth)], theta), Tensor::new(vec![2, batch, n], x)];
    let got = xla.run(entry, &inputs).expect("xla run");
    let want = native.run(entry, &inputs).expect("native run");
    assert_close(&got[0].data, &want[0].data, 2e-2, entry);
}
