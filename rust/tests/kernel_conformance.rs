//! Kernel conformance property suite: every SIMD backend is pinned
//! against the scalar reference implementation.
//!
//! Contract under test (see `src/kernels/mod.rs` module docs):
//!
//! * **Elementwise kernels are bitwise identical** across backends,
//!   including odd/tail lanes — they perform no fused multiply-adds and
//!   no cross-lane reduction, so vectorization cannot change a single
//!   rounding. These are asserted with `f32::to_bits` equality.
//! * **`dot_acc` is the one reassociating kernel**: SIMD backends keep
//!   `LANES` FMA partial sums and reduce them left-to-right, so bitwise
//!   equality is impossible by design. Its documented contract is the
//!   relative bound `|scalar − simd| ≤ 1e-6 · max(1, |init| + Σ|aᵢ·bᵢ|)`
//!   (each fused/reassociated op perturbs by ≤ ε·|term|; 1e-6 ≈ 8ε gives
//!   slack for the lane-count partial sums at every size tested here).
//!
//! Sweep: batch/lane sizes {1, 3, 8, 64} plus vector-width straddling
//! tails for both 4-lane (NEON) and 8-lane (AVX2) backends, and problem
//! sizes N ∈ {8 … 1024} for the reduction, span, and end-to-end checks.

use butterfly::kernels::{self, Backend, TwSpan, TwSpanMut};

/// Batch-lane sizes: the required {1, 3, 8, 64} plus straddling tails
/// around the 4-lane and 8-lane vector widths.
const LANES: &[usize] = &[1, 2, 3, 4, 5, 7, 8, 9, 12, 13, 16, 17, 31, 33, 64, 65];

/// Problem sizes for the reduction / span sweeps.
const NS: &[usize] = &[8, 16, 32, 64, 128, 256, 512, 1024];

/// Deterministic LCG fill, values in (−1, 1), no zeros/NaNs — mixed
/// signs so relu/select paths exercise both branches.
fn fill(seed: u64, n: usize) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = ((s >> 33) as u32 as f32) / (u32::MAX as f32) * 2.0 - 1.0;
            if v == 0.0 {
                0.5
            } else {
                v
            }
        })
        .collect()
}

/// Every backend other than scalar that this CPU can run.
fn simd_backends() -> Vec<Backend> {
    Backend::all()
        .into_iter()
        .filter(|be| *be != Backend::Scalar && be.available())
        .collect()
}

#[track_caller]
fn assert_bits(scalar: &[f32], simd: &[f32], kernel: &str, be: Backend, n: usize) {
    for (i, (a, b)) in scalar.iter().zip(simd).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{kernel}: backend {} diverges from scalar at lane {i}/{n} ({a} vs {b})",
            be.name()
        );
    }
}

/// Run `f` once under scalar and once under `be` on freshly cloned
/// buffers, then assert every mutated buffer is bitwise identical.
#[track_caller]
fn check_bitwise<F>(bufs: &[Vec<f32>], be: Backend, kernel: &str, mut f: F)
where
    F: FnMut(Backend, &mut [Vec<f32>]),
{
    let mut s: Vec<Vec<f32>> = bufs.to_vec();
    let mut v: Vec<Vec<f32>> = bufs.to_vec();
    f(Backend::Scalar, &mut s);
    f(be, &mut v);
    for (sb, vb) in s.iter().zip(&v) {
        assert_bits(sb, vb, kernel, be, sb.len());
    }
}

#[test]
fn elementwise_kernels_bitwise_across_backends_and_tails() {
    for be in simd_backends() {
        for &n in LANES {
            let x = fill(1, n);
            let y = fill(2, n);
            let z = fill(3, n);
            let w = fill(4, n);
            let acc1 = fill(5, n);
            let acc2 = fill(6, n);

            check_bitwise(&[x.clone(), y.clone()], be, "bf2_real", |b, m| {
                let [lo, hi] = m else { unreachable!() };
                kernels::bf2_real(b, 0.8, -0.3, 0.55, 1.1, lo, hi);
            });
            let g: [f32; 8] = [0.9, -0.2, 0.4, 0.3, -0.6, 0.1, 1.05, -0.8];
            check_bitwise(
                &[x.clone(), y.clone(), z.clone(), w.clone()],
                be,
                "bf2_complex",
                |b, m| {
                    let [rlo, ilo, rhi, ihi] = m else { unreachable!() };
                    kernels::bf2_complex(b, &g, rlo, ilo, rhi, ihi);
                },
            );
            check_bitwise(&[acc1.clone()], be, "axpy_set", |b, m| {
                kernels::axpy_set(b, 0.73, &x, &mut m[0]);
            });
            check_bitwise(&[acc1.clone()], be, "axpy_acc", |b, m| {
                kernels::axpy_acc(b, -0.37, &x, &mut m[0]);
            });
            check_bitwise(&[acc1.clone(), acc2.clone()], be, "axpy2_acc", |b, m| {
                let [o1, o2] = m else { unreachable!() };
                kernels::axpy2_acc(b, 0.41, &x, &y, o1, o2);
            });
            check_bitwise(&[acc1.clone(), acc2.clone()], be, "caxpy_set", |b, m| {
                let [o1, o2] = m else { unreachable!() };
                kernels::caxpy_set(b, 0.6, -0.75, &x, &y, o1, o2);
            });
            check_bitwise(&[acc1.clone(), acc2.clone()], be, "caxpy_acc", |b, m| {
                let [o1, o2] = m else { unreachable!() };
                kernels::caxpy_acc(b, 0.6, -0.75, &x, &y, o1, o2);
            });
            check_bitwise(&[acc1.clone(), acc2.clone()], be, "cmul_acc", |b, m| {
                let [o1, o2] = m else { unreachable!() };
                kernels::cmul_acc(b, 0.6, -0.75, &x, &y, o1, o2);
            });
            check_bitwise(
                &[x.clone(), y.clone(), z.clone(), w.clone()],
                be,
                "fft_bf",
                |b, m| {
                    let [rl, il, rh, ih] = m else { unreachable!() };
                    kernels::fft_bf(b, 0.31, -0.95, rl, il, rh, ih);
                },
            );
            check_bitwise(&[x.clone(), y.clone()], be, "fwht_pair", |b, m| {
                let [lo, hi] = m else { unreachable!() };
                kernels::fwht_pair(b, std::f32::consts::FRAC_1_SQRT_2, lo, hi);
            });
            check_bitwise(&[x.clone(), y.clone()], be, "cmul_scalar", |b, m| {
                let [re, im] = m else { unreachable!() };
                kernels::cmul_scalar(b, -0.42, 0.87, re, im);
            });
            check_bitwise(&[x.clone()], be, "scale", |b, m| {
                kernels::scale(b, 1.37, &mut m[0]);
            });
            check_bitwise(&[acc1.clone()], be, "rot_scale", |b, m| {
                kernels::rot_scale(b, 0.92, -0.39, 0.5, &x, &y, &mut m[0]);
            });
            check_bitwise(&[acc1.clone()], be, "sub_scale", |b, m| {
                kernels::sub_scale(b, 0.707, &x, &y, &mut m[0]);
            });
            check_bitwise(&[acc1.clone()], be, "relu_fwd", |b, m| {
                kernels::relu_fwd(b, &x, &mut m[0]);
            });
            check_bitwise(&[acc1.clone()], be, "relu_bwd", |b, m| {
                kernels::relu_bwd(b, &x, &y, &mut m[0]);
            });
            check_bitwise(&[x.clone(), y.clone()], be, "sgd_step", |b, m| {
                let [p, v] = m else { unreachable!() };
                kernels::sgd_step(b, p, v, &z, 0.01, 0.9, 5e-4);
            });
            let mask: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
            check_bitwise(&[x.clone(), y.clone()], be, "masked_sgd_step", |b, m| {
                let [p, v] = m else { unreachable!() };
                kernels::masked_sgd_step(b, p, v, &z, &mask, 0.01, 0.9, 5e-4);
            });
            check_bitwise(&[acc1.clone()], be, "add_acc", |b, m| {
                kernels::add_acc(b, &x, &mut m[0]);
            });
            check_bitwise(&[z.clone(), w.clone()], be, "cmul_ew", |b, m| {
                let [xr, xi] = m else { unreachable!() };
                kernels::cmul_ew(b, &x, &y, xr, xi);
            });
            check_bitwise(&[acc1.clone(), acc2.clone()], be, "cmulc_ew", |b, m| {
                let [or_, oi] = m else { unreachable!() };
                kernels::cmulc_ew(b, &x, &y, &z, &w, or_, oi);
            });
        }
    }
}

#[test]
fn span_kernels_bitwise_across_backends_and_sizes() {
    for be in simd_backends() {
        for &n in LANES.iter().chain(NS) {
            let tw: Vec<Vec<f32>> = (0..8).map(|i| fill(10 + i, n)).collect();
            let span = TwSpan {
                g00r: &tw[0],
                g00i: &tw[1],
                g01r: &tw[2],
                g01i: &tw[3],
                g10r: &tw[4],
                g10i: &tw[5],
                g11r: &tw[6],
                g11i: &tw[7],
            };

            // forward: four data buffers mutated in place
            let data: Vec<Vec<f32>> = (0..4).map(|i| fill(20 + i, n)).collect();
            check_bitwise(&data, be, "bf2_cpx_span_fwd", |b, m| {
                let [rlo, ilo, rhi, ihi] = m else { unreachable!() };
                kernels::bf2_cpx_span_fwd(b, &span, rlo, ilo, rhi, ihi);
            });

            // backward: deltas rewritten in place + gradient accumulators
            // (pre-seeded nonzero so the accumulate order is exercised)
            let x: Vec<Vec<f32>> = (0..4).map(|i| fill(30 + i, n)).collect();
            let mut bufs: Vec<Vec<f32>> = (0..4).map(|i| fill(40 + i, n)).collect();
            bufs.extend((0..8).map(|i| fill(50 + i, n)));
            check_bitwise(&bufs, be, "bf2_cpx_span_bwd", |b, m| {
                let [d0r, d0i, d1r, d1i, g00r, g00i, g01r, g01i, g10r, g10i, g11r, g11i] = m else {
                    unreachable!()
                };
                let mut dg = TwSpanMut { g00r, g00i, g01r, g01i, g10r, g10i, g11r, g11i };
                kernels::bf2_cpx_span_bwd(b, &span, &mut dg, &x[0], &x[1], &x[2], &x[3], d0r, d0i, d1r, d1i);
            });
        }
    }
}

#[test]
fn gate_blend_identical_across_backends() {
    // gate_blend is gather-bound and runs the same scalar loop on every
    // backend by contract — pin that it really is backend-independent.
    for be in simd_backends() {
        for &n in LANES {
            let x = fill(60, n);
            let table: Vec<usize> = (0..n).map(|i| (i * 7 + 3) % n).collect();
            let mut s = vec![0.0f32; n];
            let mut v = vec![0.0f32; n];
            kernels::gate_blend(Backend::Scalar, 0.85, 0.15, &x, &table, &mut s);
            kernels::gate_blend(be, 0.85, 0.15, &x, &table, &mut v);
            assert_bits(&s, &v, "gate_blend", be, n);
        }
    }
}

#[test]
fn dot_acc_within_documented_relative_bound() {
    for be in simd_backends() {
        for &n in LANES.iter().chain(NS) {
            let a = fill(70, n);
            let b = fill(71, n);
            for init in [0.0f32, 0.37, -123.5] {
                let s = kernels::dot_acc(Backend::Scalar, init, &a, &b);
                let v = kernels::dot_acc(be, init, &a, &b);
                // documented contract: relative to the magnitude of the
                // terms actually summed, floored at 1 near cancellation
                let mag: f32 = init.abs() + a.iter().zip(&b).map(|(p, q)| (p * q).abs()).sum::<f32>();
                let tol = 1e-6 * mag.max(1.0);
                assert!(
                    (s - v).abs() <= tol,
                    "dot_acc: backend {} exceeds relative bound at n={n} init={init}: \
                     scalar={s} simd={v} |Δ|={} tol={tol}",
                    be.name(),
                    (s - v).abs()
                );
            }
        }
    }
}

/// End-to-end: a whole serving apply and a whole training loss+grad are
/// bitwise identical under every backend, because every kernel on those
/// paths is elementwise. This is the only test in the suite that flips
/// the process-wide backend override, and it is confined to this single
/// `#[test]` so the file stays race-free under the parallel test runner.
#[test]
fn end_to_end_apply_and_training_bitwise_across_backends() {
    use butterfly::butterfly::fast::{BatchWorkspace, FastBp};
    use butterfly::runtime::bench::recovery_workload;

    let natives = simd_backends();
    if natives.is_empty() {
        return; // scalar-only host: nothing to compare
    }
    let prev = kernels::active();
    for &n in &[8usize, 64, 256] {
        let (stack, loss) = recovery_workload(n, 64.min(n), 11);
        let fast = FastBp::from_stack(&stack);
        for &batch in &[1usize, 3, 8, 64] {
            let re0 = fill(80, n * batch);
            let im0 = fill(81, n * batch);
            let mut ws = BatchWorkspace::new();
            kernels::set_active(Backend::Scalar);
            let (mut sre, mut sim) = (re0.clone(), im0.clone());
            fast.apply_complex_batch_col(&mut sre, &mut sim, batch, &mut ws);
            for &be in &natives {
                kernels::set_active(be);
                let (mut vre, mut vim) = (re0.clone(), im0.clone());
                fast.apply_complex_batch_col(&mut vre, &mut vim, batch, &mut ws);
                assert_bits(&sre, &vre, "apply_complex_batch_col re", be, n * batch);
                assert_bits(&sim, &vim, "apply_complex_batch_col im", be, n * batch);
            }
        }
        // training loss + full gradient vector, scalar vs each backend
        kernels::set_active(Backend::Scalar);
        let mut sg = stack.zero_grad();
        let sl = loss.loss_and_grad(&stack, &mut sg);
        for &be in &natives {
            kernels::set_active(be);
            let mut vg = stack.zero_grad();
            let vl = loss.loss_and_grad(&stack, &mut vg);
            assert_eq!(sl.to_bits(), vl.to_bits(), "loss under {} at n={n}", be.name());
            for (sm, vm) in sg.iter().zip(&vg) {
                assert_bits(sm, vm, "stack gradient", be, sm.len());
            }
        }
    }
    kernels::set_active(prev);
}
