//! Property tests for closed-form butterfly identification
//! (`butterfly::identify`): exactly-butterfly targets must be recovered
//! to fp32 roundoff with **zero optimizer steps** across the paper's
//! size range, and on near-butterfly targets the truncated
//! hierarchical-SVD projection must beat random initialization as a
//! warm start.

use butterfly::butterfly::identify::EXACT_REL_RMSE;
use butterfly::butterfly::{identify, peel_butterfly, BpModule, BpParams, BpStack};
use butterfly::butterfly::{Field, InitScheme, PermTying, TwiddleTying};
use butterfly::linalg::dense::CMat;
use butterfly::transforms::matrices;
use butterfly::util::rng::Rng;

fn relative_rmse(stack: &BpStack, target: &CMat) -> f64 {
    let n = target.rows;
    stack.rmse_to(target) / (target.frobenius_norm() / n as f64).max(1e-30)
}

#[test]
fn dft_recovered_to_fp32_roundoff_with_zero_steps() {
    for n in [16usize, 64, 256, 1024] {
        let target = matrices::dft_matrix(n);
        let got = identify(&target);
        assert!(
            got.exact,
            "n={n}: relative rmse {} via {}, want < {EXACT_REL_RMSE}",
            got.relative, got.method
        );
        assert_eq!(got.method, "butterfly/bit-reversal", "n={n}");
        // `exact` is derived from this same stack, but recompute
        // independently so the flag can't drift from the stack it ships
        assert!(relative_rmse(&got.stack, &target) < EXACT_REL_RMSE, "n={n}");
    }
}

#[test]
fn hadamard_recovered_to_fp32_roundoff_with_zero_steps() {
    for n in [16usize, 64, 256, 1024] {
        let target = matrices::hadamard_matrix(n).to_cmat();
        let got = identify(&target);
        assert!(
            got.exact,
            "n={n}: relative rmse {} via {}, want < {EXACT_REL_RMSE}",
            got.relative, got.method
        );
        assert_eq!(got.method, "butterfly/identity", "n={n}");
    }
}

#[test]
fn idft_and_random_circulants_recovered() {
    let idft = matrices::idft_matrix(64);
    let got = identify(&idft);
    assert!(got.exact, "idft: relative {} via {}", got.relative, got.method);

    let mut rng = Rng::new(41);
    for n in [32usize, 128] {
        let mut h = vec![0.0f32; n];
        rng.fill_normal(&mut h, 0.0, (1.0 / n as f64).sqrt() as f32);
        let target = matrices::circulant_matrix(&h).to_cmat();
        let got = identify(&target);
        assert!(got.exact, "circulant n={n}: relative {} via {}", got.relative, got.method);
        assert!(got.method.starts_with("kmatrix-circulant"), "n={n}: {}", got.method);
        assert_eq!(got.stack.depth(), 2, "circulant needs the BB* depth-2 form");
    }
}

#[test]
fn warm_start_beats_random_init_on_near_butterfly_target() {
    let n = 64;
    let mut rng = Rng::new(17);
    // DFT plus entry noise at ~1% of the entry scale: no longer exactly
    // butterfly, so identification must decline exactness but return
    // the hierarchical projection as a warm start
    let scale = (1.0 / (n as f64).sqrt()) as f32;
    let base = matrices::dft_matrix(n);
    let target = CMat::from_fn(n, n, |i, j| {
        let e = base.at(i, j);
        butterfly::linalg::complex::Cpx::new(
            e.re + rng.normal_f32(0.0, 0.01 * scale),
            e.im + rng.normal_f32(0.0, 0.01 * scale),
        )
    });
    let warm = identify(&target);
    assert!(!warm.exact, "1% noise must not pass the fp32-roundoff bar");
    // random OrthogonalLike init, same shape class as the identified stack
    let mut init_rng = Rng::new(23);
    let mut p = BpParams::init(
        n,
        Field::Complex,
        TwiddleTying::Block,
        PermTying::Untied,
        InitScheme::OrthogonalLike,
        &mut init_rng,
    );
    p.fix_bit_reversal();
    let random = BpStack::new(vec![BpModule::new(p)]);
    let warm_rel = relative_rmse(&warm.stack, &target);
    let random_rel = relative_rmse(&random, &target);
    // the warm start sits at the noise floor (~1e-2); random init is
    // O(1) away — demand a conservative 5× separation
    assert!(
        warm_rel * 5.0 < random_rel,
        "warm start {warm_rel} not clearly better than random init {random_rel}"
    );
    assert!(warm_rel < 0.1, "warm start should be near the 1% noise floor, got {warm_rel}");
}

#[test]
fn peel_projection_is_idempotent() {
    // peeling the reconstruction of a peel must reproduce it: the
    // hierarchical projection lands on the butterfly manifold
    let n = 32;
    let mut rng = Rng::new(3);
    let target = CMat::from_fn(n, n, |_, _| {
        butterfly::linalg::complex::Cpx::new(rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0))
    });
    let p1 = peel_butterfly(&target);
    let m1 = BpStack::new(vec![BpModule::new(p1)]).to_matrix();
    let p2 = peel_butterfly(&m1);
    let m2 = BpStack::new(vec![BpModule::new(p2)]).to_matrix();
    let rms = (m1.frobenius_norm() / n as f64).max(1e-30);
    let rel = m2.rmse_to(&m1) / rms;
    assert!(rel < 1e-3, "re-peeling moved the projection by {rel}");
}

#[test]
fn identification_scales_without_optimizer_budget() {
    // the whole point vs the paper's §4.1 procedure: no Adam steps, no
    // Hyperband — identification is pure O(N²) linear algebra. At
    // N = 1024 the paper's search spends thousands of steps; here the
    // recovery must hold with a training budget of exactly zero.
    let n = 1024;
    let got = identify(&matrices::dft_matrix(n));
    assert!(got.exact, "n={n}: relative {}", got.relative);
    // and the identified stack is depth 1 — the minimal BP form, not a
    // padded BB* pair
    assert_eq!(got.stack.depth(), 1);
}
