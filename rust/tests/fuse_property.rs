//! Property pins for the fusion planner's boundary behavior.
//!
//! Two claims the rest of the fusion suite leans on:
//!
//! 1. **K = log N is the identity fusion.** Group size 1 copies each
//!    stage's twiddle vector verbatim and the `KsKernel` span-2 apply
//!    uses the same accumulation order as the unfused butterfly kernel,
//!    so the fused op is BITWISE the unfused `stack_op` — asserted with
//!    `f32::to_bits` equality, not a tolerance. This is what licenses
//!    `op_conformance`'s looser 1e-4 band for larger groups: any drift
//!    there comes from f64 composition ordering, not from the apply path.
//! 2. **Fusing twice is idempotent-or-rejected.** `fuse_again` succeeds
//!    only when the requested plan is exactly the plan the op already
//!    has (returning a clone); any other grouping is rejected, because
//!    the fused kernels no longer expose the per-level factors.

use butterfly::butterfly::closed_form::{dct_stack, dft_stack, hadamard_stack};
use butterfly::butterfly::module::BpStack;
use butterfly::transforms::fuse::{fuse_again, fuse_stack, plan_groups, FuseSpec, FuseStrategy};
use butterfly::transforms::op::{stack_op, LinearOp, OpWorkspace};
use butterfly::util::rng::Rng;

const STRATEGIES: [FuseStrategy; 2] = [FuseStrategy::Balanced, FuseStrategy::Memory];

/// Random planes for one op: full re/im for complex, re-only for real
/// (the natural single-plane route a real request carries).
fn planes(n: usize, batch: usize, complex: bool, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut re = vec![0.0f32; n * batch];
    let mut im = vec![0.0f32; if complex { n * batch } else { 0 }];
    let mut rng = Rng::new(seed);
    rng.fill_normal(&mut re, 0.0, 1.0);
    if complex {
        rng.fill_normal(&mut im, 0.0, 1.0);
    }
    (re, im)
}

fn test_stacks() -> Vec<(&'static str, BpStack)> {
    vec![("fft", dft_stack(64)), ("dct2", dct_stack(32)), ("fwht", hadamard_stack(64))]
}

#[test]
fn k_log_n_fusion_is_bitwise_the_unfused_stack() {
    for (label, stack) in &test_stacks() {
        let n = stack.n();
        let levels = n.trailing_zeros() as usize;
        let unfused = stack_op(*label, stack);
        for strategy in STRATEGIES {
            // both strategies degenerate to all-singleton groups at K = levels
            let spec = FuseSpec::with_k(levels, strategy);
            let fused = fuse_stack(*label, stack, &spec);
            assert_eq!(fused.groups(), vec![1usize; levels].as_slice(), "{label}");
            assert!(fused.kernel_spans().iter().all(|&s| s == 2), "{label}: singleton groups span 2");
            for batch in [1usize, 5, 64] {
                let (re0, im0) = planes(n, batch, unfused.is_complex(), 0x5EED ^ batch as u64);
                let (mut ra, mut ia) = (re0.clone(), im0.clone());
                let (mut rb, mut ib) = (re0.clone(), im0.clone());
                let mut ws = OpWorkspace::new();
                unfused.apply_batch(&mut ra, &mut ia, batch, &mut ws);
                fused.apply_batch(&mut rb, &mut ib, batch, &mut ws);
                for (i, (a, b)) in ra.iter().zip(&rb).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{label} re[{i}] batch={batch}: {a} vs {b}");
                }
                for (i, (a, b)) in ia.iter().zip(&ib).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{label} im[{i}] batch={batch}: {a} vs {b}");
                }
            }
        }
    }
}

#[test]
fn over_large_k_clamps_to_log_n_and_stays_bitwise() {
    let stack = dft_stack(16);
    let unfused = stack_op("fft16", &stack);
    // K = 99 clamps to the 4 available levels → identity fusion again
    let fused = fuse_stack("fft16", &stack, &FuseSpec::with_k(99, FuseStrategy::Balanced));
    assert!(fused.name().contains(":k4"), "clamped K shows in the name: {}", fused.name());
    assert_eq!(fused.groups(), &[1, 1, 1, 1]);
    let batch = 3usize;
    let (re0, im0) = planes(16, batch, true, 0xC1A);
    let (mut ra, mut ia) = (re0.clone(), im0.clone());
    let (mut rb, mut ib) = (re0, im0);
    let mut ws = OpWorkspace::new();
    unfused.apply_batch(&mut ra, &mut ia, batch, &mut ws);
    fused.apply_batch(&mut rb, &mut ib, batch, &mut ws);
    assert!(ra.iter().zip(&rb).all(|(a, b)| a.to_bits() == b.to_bits()));
    assert!(ia.iter().zip(&ib).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn fuse_again_is_idempotent_for_the_same_plan() {
    let stack = dft_stack(64); // 6 levels
    let spec = FuseSpec::with_k(2, FuseStrategy::Balanced); // [3, 3]
    let fused = fuse_stack("fft", &stack, &spec);
    let again = fuse_again(&fused, &spec).expect("same plan must be accepted");
    assert_eq!(again.name(), fused.name());

    // the clone computes the identical map
    let batch = 4usize;
    let (re0, im0) = planes(64, batch, true, 0xA6A1);
    let (mut ra, mut ia) = (re0.clone(), im0.clone());
    let (mut rb, mut ib) = (re0, im0);
    let mut ws = OpWorkspace::new();
    fused.apply_batch(&mut ra, &mut ia, batch, &mut ws);
    again.apply_batch(&mut rb, &mut ib, batch, &mut ws);
    assert!(ra.iter().zip(&rb).all(|(a, b)| a.to_bits() == b.to_bits()));
    assert!(ia.iter().zip(&ib).all(|(a, b)| a.to_bits() == b.to_bits()));

    // `auto` resolves to balanced K=2 at 6 levels — the same plan, so it
    // is also accepted (idempotence is about the resolved plan, not the
    // literal spec)
    assert!(fuse_again(&fused, &FuseSpec::auto()).is_ok());
}

#[test]
fn fuse_again_rejects_any_other_plan() {
    let stack = dft_stack(64); // 6 levels
    let fused = fuse_stack("fft", &stack, &FuseSpec::with_k(2, FuseStrategy::Balanced)); // [3, 3]
    // different K
    let err = fuse_again(&fused, &FuseSpec::with_k(3, FuseStrategy::Balanced)).unwrap_err();
    assert!(err.contains("already fused"), "unexpected error: {err}");
    // same K, different strategy → memory plans [4, 2] ≠ [3, 3]
    assert_eq!(plan_groups(6, 2, FuseStrategy::Memory), vec![4, 2]);
    assert!(fuse_again(&fused, &FuseSpec::with_k(2, FuseStrategy::Memory)).is_err());
    // and K = 0 never reaches the planner: the spec parser rejects it
    assert!(FuseSpec::parse("balanced:0").is_err());
}

#[test]
fn plan_groups_partition_invariants() {
    for levels in [1usize, 2, 4, 6, 9, 10, 12] {
        for k in 1..=levels {
            for strategy in STRATEGIES {
                let g = plan_groups(levels, k, strategy);
                assert_eq!(g.len(), k, "levels={levels} k={k} {strategy:?}");
                assert_eq!(g.iter().sum::<usize>(), levels, "levels={levels} k={k} {strategy:?}");
                assert!(g.iter().all(|&x| x >= 1));
                // deterministic: planning twice gives the same partition
                assert_eq!(g, plan_groups(levels, k, strategy));
            }
        }
    }
}

#[test]
fn fused_accounting_reports_actual_kernel_cost() {
    // complex DFT at N=64, balanced K=3 → groups [2, 2, 2], spans [4, 4, 4]
    let fused = fuse_stack("fft", &dft_stack(64), &FuseSpec::with_k(3, FuseStrategy::Balanced));
    assert_eq!(fused.kernel_spans(), vec![4, 4, 4]);
    // complex kernel: n·(8·span − 2) flops; weights: n·span f32 per plane
    assert_eq!(fused.flops_per_apply(), 3 * 64 * (8 * 4 - 2));
    assert_eq!(fused.kernel_bytes(), 3 * 2 * (64 * 4) * 4);

    // real FWHT at N=64, K = log N → six span-2 kernels, n·3 flops each
    let fwht = fuse_stack("fwht", &hadamard_stack(64), &FuseSpec::with_k(6, FuseStrategy::Memory));
    assert_eq!(fwht.flops_per_apply(), 6 * 64 * 3);
    assert_eq!(fwht.kernel_bytes(), 6 * (64 * 2) * 4);

    // depth-2 stack: the per-stage plan is repeated for every stage
    let dct = fuse_stack("dct2", &dct_stack(32), &FuseSpec::with_k(2, FuseStrategy::Balanced));
    let spans = dct.kernel_spans();
    assert_eq!(spans.len(), 2 * dct.k(), "two stages × K kernels");
    assert_eq!(&spans[..2], &spans[2..], "same plan in both stages");
}
