//! Figure 4 (left): training-step speed at the paper's setting
//! (N = 1024, batch 256): butterfly forward+backward vs dense GEMM
//! forward+backward, with the batched FFT as the specialized lower
//! bound.
//!
//! Paper claim shape: butterfly training is *faster than dense GEMM*
//! (they report 15% faster on GPU) and within a small factor of the FFT.

use butterfly::butterfly::params::Field;
use butterfly::nn::butterfly_layer::ButterflyLayer;
use butterfly::nn::layers::{DenseLayer, Layer};
use butterfly::transforms::fast::FftPlan;
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{bench, black_box, smoke_mode, BenchConfig};

fn main() {
    let mut cfg = BenchConfig::from_env();
    cfg.runs = cfg.runs.min(5); // steps are heavy
    // smoke shrinks the paper setting (N=1024, batch 256) so the CI
    // execution pass stays fast; FIG4_N/FIG4_BATCH still override
    let (def_n, def_batch) = if smoke_mode() { (256usize, 64usize) } else { (1024, 256) };
    let n = std::env::var("FIG4_N").ok().and_then(|v| v.parse().ok()).unwrap_or(def_n);
    let batch = std::env::var("FIG4_BATCH").ok().and_then(|v| v.parse().ok()).unwrap_or(def_batch);
    let mut rng = Rng::new(3);
    let mut x = vec![0.0f32; batch * n];
    rng.fill_normal(&mut x, 0.0, 1.0);

    // butterfly BPBP fwd+bwd (the paper's trained module)
    let mut bfly = ButterflyLayer::new(n, 2, Field::Real, &mut rng);
    let bf = bench(&cfg, || {
        let y = bfly.forward(black_box(&x), batch, true);
        bfly.zero_grad();
        black_box(bfly.backward(&y, batch));
    })
    .median();

    // dense GEMM fwd+bwd
    let mut dense = DenseLayer::new(n, n, &mut rng);
    let dn = bench(&cfg, || {
        let y = dense.forward(black_box(&x), batch, true);
        dense.zero_grad();
        black_box(dense.backward(&y, batch));
    })
    .median();

    // batched FFT (specialized lower bound; forward only ×3 to mimic
    // fwd+bwd cost of a linear layer)
    let plan = FftPlan::new(n);
    let mut re = x.clone();
    let mut im = vec![0.0f32; batch * n];
    let ff = bench(&cfg, || {
        for b in 0..batch {
            plan.forward(&mut re[b * n..(b + 1) * n], &mut im[b * n..(b + 1) * n]);
        }
        black_box(&mut re);
    })
    .median()
        * 3.0;

    let mut t = Table::new(&["method", "step ms", "vs dense"])
        .with_title(format!("Figure 4 (left): fwd+bwd step, N={n}, batch={batch}"));
    t.add_row(vec!["dense GEMM".into(), format!("{:.1}", dn / 1e6), "1.00x".into()]);
    t.add_row(vec!["butterfly BPBP".into(), format!("{:.1}", bf / 1e6), format!("{:.2}x", dn / bf)]);
    t.add_row(vec!["FFT ×3 (bound)".into(), format!("{:.1}", ff / 1e6), format!("{:.2}x", dn / ff)]);
    println!("{}", t.render());
    println!("paper shape: butterfly trains faster than dense GEMM at N=1024.");
}
