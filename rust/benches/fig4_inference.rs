//! Figure 4 (right): single-vector inference speed across N —
//! the learned butterfly fast multiply (BP) vs dense GEMV, and vs the
//! hand-written FFT / DCT / DST this library also implements.
//!
//! The paper's claim shapes to verify: BP is 1–2 orders of magnitude
//! faster than GEMV at large N, within ~5× of the FFT and ~3× of
//! DCT/DST — all single-threaded.

use butterfly::butterfly::closed_form::dft_stack;
use butterfly::butterfly::fast::{BatchWorkspace, FastBp, Workspace};
use butterfly::linalg::dense::Mat;
use butterfly::transforms::fast::{FftPlan, RealTransformPlan};
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{bench, black_box, smoke_mode, BenchConfig};

fn main() {
    let cfg = BenchConfig::from_env();
    // smoke keeps two sizes so the N-scaling columns still render
    let ns: &[usize] = if smoke_mode() { &[64, 256] } else { &[64, 128, 256, 512, 1024, 2048] };
    let mut table = Table::new(&[
        "N", "GEMV ns", "BP ns", "BP ns/vec B=64", "FFT ns", "DCT ns", "DST ns", "BP/GEMV speedup", "BP/FFT ratio",
    ])
    .with_title("Figure 4 (right): transform timings (single-threaded; batched column amortizes twiddle loads)");

    for &n in ns {
        let mut rng = Rng::new(7);
        // dense real GEMV (the O(N²) baseline)
        let dense = Mat::from_fn(n, n, |_, _| rng.normal_f32(0.0, 1.0));
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let mut y = vec![0.0f32; n];
        let gemv = bench(&cfg, || dense.matvec_into(black_box(&x), &mut y)).median();

        // learned butterfly (hardened closed-form DFT stack = what a
        // trained BP model serves)
        let fast = FastBp::from_stack(&dft_stack(n));
        let mut ws = Workspace::new(n);
        let mut re = x.clone();
        let mut im = vec![0.0f32; n];
        let bp = bench(&cfg, || {
            re.copy_from_slice(&x);
            im.iter_mut().for_each(|v| *v = 0.0);
            fast.apply_complex(black_box(&mut re), black_box(&mut im), &mut ws);
        })
        .median();

        // batched butterfly: one apply for 64 vectors, column-major
        let bsize = 64usize;
        let mut bws = BatchWorkspace::with_capacity(bsize, n);
        let mut bre = vec![0.0f32; bsize * n];
        let mut bim = vec![0.0f32; bsize * n];
        Rng::new(8).fill_normal(&mut bre, 0.0, 1.0);
        let bp_batch = bench(&cfg, || {
            fast.apply_complex_batch_col(black_box(&mut bre), black_box(&mut bim), bsize, &mut bws);
        })
        .median()
            / bsize as f64;

        // specialized transforms
        let plan = FftPlan::new(n);
        let mut fr = x.clone();
        let mut fi = vec![0.0f32; n];
        let fft = bench(&cfg, || {
            fr.copy_from_slice(&x);
            fi.iter_mut().for_each(|v| *v = 0.0);
            plan.forward(black_box(&mut fr), black_box(&mut fi));
        })
        .median();
        let rplan = RealTransformPlan::new(n);
        let mut out = vec![0.0f32; n];
        let (mut sre, mut sim) = (Vec::new(), Vec::new());
        let dct = bench(&cfg, || rplan.dct2(black_box(&x), &mut out, &mut sre, &mut sim)).median();
        let dst = bench(&cfg, || rplan.dst2(black_box(&x), &mut out, &mut sre, &mut sim)).median();

        table.add_row(vec![
            n.to_string(),
            format!("{gemv:.0}"),
            format!("{bp:.0}"),
            format!("{bp_batch:.0}"),
            format!("{fft:.0}"),
            format!("{dct:.0}"),
            format!("{dst:.0}"),
            format!("{:.1}x", gemv / bp),
            format!("{:.2}x", bp / fft),
        ]);
    }
    println!("{}", table.render());
    println!("paper shape: BP ≫ GEMV at large N (1–2 orders), BP within ~5x of FFT;");
    println!("batched BP (B=64) should beat single-vector BP per vector at every N.");
}
