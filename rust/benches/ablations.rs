//! E7 ablations (DESIGN.md): design choices of the parameterization,
//! each measured as recovery RMSE on the N=16 DFT after a fixed Adam
//! budget (3 seeds, best kept).
//!
//! Axes: permutation-logit tying (paper §3.3), learned vs fixed
//! permutation, init scheme (§3.2), real vs complex field, twiddle
//! weight-tying (paper-tied vs untied blocks).

use butterfly::butterfly::module::{BpModule, BpStack, FactorizeLoss};
use butterfly::butterfly::params::{BpParams, Field, InitScheme, PermTying, TwiddleTying};
use butterfly::opt::adam::Adam;
use butterfly::transforms::matrices::dft_matrix;
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use butterfly::util::timer::smoke_mode;

struct Variant {
    name: &'static str,
    field: Field,
    twiddle: TwiddleTying,
    perm: PermTying,
    init: InitScheme,
    fix_bitrev: bool,
}

fn run(v: &Variant, n: usize, steps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut p = BpParams::init(n, v.field, v.twiddle, v.perm, v.init, &mut rng);
    if v.fix_bitrev {
        p.fix_bit_reversal();
    }
    let stack = BpStack::new(vec![BpModule::new(p)]);
    let mask: Vec<f32> = stack.modules[0].params.trainable_mask();
    let loss_fn = FactorizeLoss::new(dft_matrix(n));
    let mut stack = stack;
    let mut adam = Adam::new(stack.modules[0].params.data.len(), 0.05);
    let mut best = f64::INFINITY;
    for _ in 0..steps {
        let mut grad = stack.zero_grad();
        let loss = loss_fn.loss_and_grad(&stack, &mut grad);
        best = best.min(loss.sqrt());
        if best < 1e-4 {
            break;
        }
        adam.step(&mut stack.modules[0].params.data, &grad[0], Some(&mask));
    }
    best
}

fn main() {
    let fast = smoke_mode();
    let steps = if fast { 300 } else { 2000 };
    let n = 16;
    let variants = [
        Variant {
            name: "paper default (complex, factor-tied, untied logits, orth init)",
            field: Field::Complex,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Untied,
            init: InitScheme::OrthogonalLike,
            fix_bitrev: false,
        },
        Variant {
            name: "tied perm logits (3 params)",
            field: Field::Complex,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Tied,
            init: InitScheme::OrthogonalLike,
            fix_bitrev: false,
        },
        Variant {
            name: "fixed bit-reversal perm (oracle permutation)",
            field: Field::Complex,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Untied,
            init: InitScheme::OrthogonalLike,
            fix_bitrev: true,
        },
        Variant {
            name: "untied twiddle blocks (2N log N params)",
            field: Field::Complex,
            twiddle: TwiddleTying::Block,
            perm: PermTying::Untied,
            init: InitScheme::OrthogonalLike,
            fix_bitrev: false,
        },
        Variant {
            name: "real field (DFT needs complex — expected to fail)",
            field: Field::Real,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Untied,
            init: InitScheme::OrthogonalLike,
            fix_bitrev: false,
        },
        Variant {
            name: "near-identity init",
            field: Field::Complex,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Untied,
            init: InitScheme::NearIdentity { noise: 0.1 },
            fix_bitrev: false,
        },
        Variant {
            name: "random-rotation init",
            field: Field::Complex,
            twiddle: TwiddleTying::Factor,
            perm: PermTying::Untied,
            init: InitScheme::RandomRotation,
            fix_bitrev: false,
        },
    ];
    let mut table = Table::new(&["variant", "best RMSE (3 seeds)", "trainable params"])
        .with_title(format!("Ablations: DFT N={n}, {steps} Adam steps"));
    for v in &variants {
        let mut best = f64::INFINITY;
        for seed in 1..=3 {
            best = best.min(run(v, n, steps, seed));
            if best < 1e-4 {
                break;
            }
        }
        let mut rng = Rng::new(0);
        let mut p = BpParams::init(n, v.field, v.twiddle, v.perm, v.init, &mut rng);
        if v.fix_bitrev {
            p.fix_bit_reversal();
        }
        table.add_row(vec![v.name.to_string(), fmt_sci(best), p.trainable_len().to_string()]);
    }
    println!("{}", table.render());
    println!("expected: complex variants recover; the real field cannot represent the DFT;");
    println!("fixed bit-reversal converges fastest (the permutation is the hard part).");
}
