//! Table 1 (§4.2) compression sweep: the `compress` workload as a bench.
//!
//! Two tables:
//!
//! 1. **Training throughput** — minibatch SGD steps/sec of the legacy
//!    allocating `train_step` vs the chunk-parallel workspace engine
//!    (`MlpTrainer`) at T ∈ {1, 2, 4} for each hidden class. The engine
//!    is bit-identical across T, so the sweep shows pure wall-clock.
//! 2. **Inference speed of the exported ops** — ns/vector of each
//!    trained hidden layer served through its `LinearOp` fast form at
//!    B ∈ {1, 64}: the O(N log N) vs O(N²) story at serving batch sizes
//!    (paper's "4× faster inference" axis).
//!
//! `BUTTERFLY_BENCH_SMOKE=1` (or `--smoke`) shrinks sizes for the CI
//! smoke run.

use butterfly::nn::mlp::HiddenKind;
use butterfly::nn::CompressMlp;
use butterfly::runtime::bench::{compress_steps_per_sec, scenario_seed};
use butterfly::transforms::op::{bench_nanos_per_vec, LinearOp};
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{black_box, smoke_mode};
use std::time::Instant;

fn batch_of(n: usize, bsz: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; bsz * n];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<u8> = (0..bsz).map(|i| (i % classes) as u8).collect();
    (x, y)
}

fn legacy_steps_per_sec(kind: HiddenKind, n: usize, bsz: usize, steps: usize) -> f64 {
    let classes = 10;
    let mut model = CompressMlp::new(kind, n, classes, &mut Rng::new(3));
    let (x, y) = batch_of(n, bsz, classes, 5);
    black_box(model.train_step(&x, &y, 0.02, 0.9, 0.0));
    let t0 = Instant::now();
    for _ in 0..steps {
        black_box(model.train_step(&x, &y, 0.02, 0.9, 0.0));
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let fast = smoke_mode();
    let kinds = [
        HiddenKind::Dense,
        HiddenKind::BpbpReal,
        HiddenKind::BpbpComplex,
        HiddenKind::LowRank { rank: 4 },
        HiddenKind::Circulant,
    ];

    // ---- training throughput ---------------------------------------
    let ns: &[usize] = if fast { &[64] } else { &[64, 256, 1024] };
    let threads: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    let bsz = 50; // the paper's batch size
    let mut header = vec!["hidden".to_string(), "n".to_string(), "legacy sps".to_string()];
    for &t in threads {
        header.push(format!("engine {t}T sps"));
    }
    let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table =
        Table::new(&cols).with_title("table1 training: SGD steps/sec (batch 50), legacy vs chunk-parallel engine");
    for &n in ns {
        for &kind in &kinds {
            let steps = if fast {
                4
            } else {
                match n {
                    64 => 40,
                    256 => 16,
                    _ => 3,
                }
            };
            // the dense 1024² legacy path is very slow; thin it further
            let steps = if matches!(kind, HiddenKind::Dense) && n >= 1024 { steps.min(2) } else { steps };
            let mut row = vec![kind.name(), n.to_string(), format!("{:.1}", legacy_steps_per_sec(kind, n, bsz, steps))];
            for &t in threads {
                // the shared engine harness (runtime::bench) — pristine
                // model per call, same loop the bench CLI commits
                let seed = scenario_seed(&format!("benches/table1/{}/n{n}/T{t}", kind.name()));
                row.push(format!("{:.1}", compress_steps_per_sec(kind, n, bsz, t, 8, steps, seed)));
            }
            table.add_row(row);
        }
    }
    println!("{}", table.render());
    println!("acceptance shape: engine 1T ≥ legacy (no allocation traffic), engine");
    println!("scaling with T on the structured classes at n ≥ 256.");

    // ---- exported-op inference speed -------------------------------
    let n = if fast { 64 } else { 1024 };
    let mut table = Table::new(&["hidden", "op", "flops/apply", "ns/vec B=1", "ns/vec B=64"])
        .with_title(format!("table1 inference: exported hidden-layer ops at n = {n}"));
    for &kind in &kinds {
        let model = CompressMlp::new(kind, n, 10, &mut Rng::new(7));
        let op = model.export_hidden_op();
        let iters = if fast { 5 } else { 40 };
        table.add_row(vec![
            kind.name(),
            op.name().to_string(),
            op.flops_per_apply().to_string(),
            format!("{:.0}", bench_nanos_per_vec(op.as_ref(), 1, iters)),
            format!("{:.0}", bench_nanos_per_vec(op.as_ref(), 64, iters)),
        ]);
    }
    println!("{}", table.render());
    println!("paper shape: butterfly/circulant/low-rank ops beat the dense matvec at");
    println!("n = 1024 (the Table 1 'faster inference' axis), batched amortizes further.");
}
