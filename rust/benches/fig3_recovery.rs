//! Figure 3 / Appendix Table 4 (reduced grid, wall-clock bounded):
//! recovery RMSE for all eight transforms at small N under the
//! coordinator's Hyperband procedure, with the three baselines at equal
//! multiply budget. The full-size grid is `examples/transform_zoo.rs`.

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline, sparse_plus_lowrank_baseline};
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::transforms::matrices::target_matrix;
use butterfly::transforms::spec::ALL_TRANSFORMS;
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use std::time::Instant;

fn main() {
    let fast = std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let ns: &[usize] = if fast { &[8] } else { &[8, 16, 32] };
    let cfg = SchedulerConfig {
        workers: 0,
        max_resource: if fast { 9 } else { 27 },
        eta: 3,
        step_quantum: if fast { 30 } else { 80 },
        seed: 42,
    };
    let mut table = Table::new(&["transform", "N", "butterfly", "sparse", "low-rank", "sparse+lr", "secs"])
        .with_title("Figure 3 (reduced): RMSE at equal multiplication budget");
    for kind in ALL_TRANSFORMS {
        for &n in ns {
            let t0 = Instant::now();
            let job = FactorizeJob::paper(kind, n, 42, 30_000);
            let res = run_job(&job, &cfg, &Metrics::new(), &Registry::new());
            let mut rng = Rng::new(42);
            let target = target_matrix(kind, n, &mut rng);
            let budget = butterfly_budget(n, kind.recommended_depth());
            table.add_row(vec![
                kind.name().to_string(),
                n.to_string(),
                fmt_sci(res.best_rmse),
                fmt_sci(sparse_baseline(&target, budget).rmse),
                fmt_sci(lowrank_baseline(&target, budget).rmse),
                fmt_sci(sparse_plus_lowrank_baseline(&target, budget).rmse),
                format!("{:.1}", t0.elapsed().as_secs_f64()),
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper shape: butterfly ≈ machine precision on the recursive transforms,");
    println!("baselines plateau; legendre partially recovered; randn unrecoverable by all.");
}
