//! Figure 3 / Appendix Table 4 (reduced grid, wall-clock bounded):
//! recovery RMSE for all eight transforms at small N under the
//! coordinator's Hyperband procedure, with the three baselines at equal
//! multiply budget — plus the **training-engine throughput sweep** that
//! gates recovery wall-clock: Adam steps/sec of the allocating
//! `loss_and_grad` path vs the workspace engine (`loss_and_grad_ws` /
//! `loss_and_grad_parallel`) over n × chunk × threads.
//!
//! The full-size RMSE grid is `examples/transform_zoo.rs`.

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline, sparse_plus_lowrank_baseline};
use butterfly::butterfly::module::{BpModule, BpStack, FactorizeLoss};
use butterfly::butterfly::params::{BpParams, Field, InitScheme, PermTying, TwiddleTying};
use butterfly::butterfly::workspace::ParallelTrainer;
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::transforms::matrices::target_matrix;
use butterfly::transforms::spec::{TransformKind, ALL_TRANSFORMS};
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use butterfly::util::timer::black_box;
use std::time::Instant;

fn train_stack(n: usize, seed: u64) -> BpStack {
    let mut rng = Rng::new(seed);
    let mut p = BpParams::init(
        n,
        Field::Complex,
        TwiddleTying::Factor,
        PermTying::Untied,
        InitScheme::OrthogonalLike,
        &mut rng,
    );
    for k in 0..p.levels {
        for g in 0..3 {
            p.set_logit(k, g, rng.normal_f32(0.0, 1.0));
        }
    }
    BpStack::new(vec![BpModule::new(p)])
}

/// Steps/sec of the allocating path: fresh grad buffers + per-chunk
/// allocations every step, exactly as the pre-workspace `Trial::advance`
/// hot loop behaved.
fn steps_per_sec_alloc(loss: &FactorizeLoss, stack: &BpStack, steps: usize) -> f64 {
    // warmup
    let mut grad = stack.zero_grad();
    black_box(loss.loss_and_grad(stack, &mut grad));
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut grad = stack.zero_grad();
        black_box(loss.loss_and_grad(stack, &mut grad));
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

/// Steps/sec of the workspace engine at a given thread count (1 ⇒ the
/// serial `loss_and_grad_ws` path): persistent grads + workspace.
fn steps_per_sec_ws(loss: &FactorizeLoss, stack: &BpStack, threads: usize, steps: usize) -> f64 {
    let mut pool = ParallelTrainer::new(stack.n(), threads);
    let mut grad = stack.zero_grad();
    // warmup (also sizes every buffer)
    black_box(loss.loss_and_grad_parallel(stack, &mut grad, &mut pool));
    let t0 = Instant::now();
    for _ in 0..steps {
        for g in grad.iter_mut() {
            g.iter_mut().for_each(|v| *v = 0.0);
        }
        black_box(loss.loss_and_grad_parallel(stack, &mut grad, &mut pool));
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn engine_sweep(fast: bool) {
    let ns: &[usize] = if fast { &[64] } else { &[64, 256, 1024] };
    let chunks: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256] };
    let threads: &[usize] = if fast { &[1, 4] } else { &[1, 4, 8] };
    let mut header = vec!["n".to_string(), "chunk".to_string(), "alloc 1T sps".to_string()];
    for &t in threads {
        header.push(format!("ws {t}T sps"));
    }
    header.push("ws/alloc 1T".to_string());
    let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&cols)
        .with_title("fig3 engine: Adam steps/sec, allocating path vs workspace engine");
    for &n in ns {
        let stack = train_stack(n, 7);
        let mut rng = Rng::new(42);
        let target = target_matrix(TransformKind::Dft, n, &mut rng);
        let steps = if fast {
            8
        } else {
            match n {
                64 => 60,
                256 => 16,
                _ => 4,
            }
        };
        for &chunk in chunks {
            if chunk > n {
                continue;
            }
            let mut loss = FactorizeLoss::new(target.clone());
            loss.chunk = chunk;
            let alloc_sps = steps_per_sec_alloc(&loss, &stack, steps);
            let mut row = vec![n.to_string(), chunk.to_string(), format!("{alloc_sps:.1}")];
            let mut ws1 = 0.0;
            for &t in threads {
                let sps = steps_per_sec_ws(&loss, &stack, t, steps);
                if t == 1 {
                    ws1 = sps;
                }
                row.push(format!("{sps:.1}"));
            }
            row.push(format!("{:.2}x", ws1 / alloc_sps));
            table.add_row(row);
        }
    }
    println!("{}", table.render());
    println!("acceptance shape: ws 1T ≥ 2x alloc at n = 256 (twiddle hoisting +");
    println!("zero steady-state allocations), near-linear ws scaling to 4T.");
}

fn main() {
    let fast = std::env::var("BENCH_FAST").ok().as_deref() == Some("1");

    engine_sweep(fast);

    let ns: &[usize] = if fast { &[8] } else { &[8, 16, 32] };
    let cfg = SchedulerConfig {
        workers: 0,
        max_resource: if fast { 9 } else { 27 },
        eta: 3,
        step_quantum: if fast { 30 } else { 80 },
        seed: 42,
    };
    let mut table = Table::new(&["transform", "N", "butterfly", "sparse", "low-rank", "sparse+lr", "secs"])
        .with_title("Figure 3 (reduced): RMSE at equal multiplication budget");
    for kind in ALL_TRANSFORMS {
        for &n in ns {
            let t0 = Instant::now();
            let job = FactorizeJob::paper(kind, n, 42, 30_000);
            let res = run_job(&job, &cfg, &Metrics::new(), &Registry::new());
            let mut rng = Rng::new(42);
            let target = target_matrix(kind, n, &mut rng);
            let budget = butterfly_budget(n, kind.recommended_depth());
            table.add_row(vec![
                kind.name().to_string(),
                n.to_string(),
                fmt_sci(res.best_rmse),
                fmt_sci(sparse_baseline(&target, budget).rmse),
                fmt_sci(lowrank_baseline(&target, budget).rmse),
                fmt_sci(sparse_plus_lowrank_baseline(&target, budget).rmse),
                format!("{:.1}", t0.elapsed().as_secs_f64()),
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper shape: butterfly ≈ machine precision on the recursive transforms,");
    println!("baselines plateau; legendre partially recovered; randn unrecoverable by all.");
}
