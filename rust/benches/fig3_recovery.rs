//! Figure 3 / Appendix Table 4 (reduced grid, wall-clock bounded):
//! recovery RMSE for all eight transforms at small N under the
//! coordinator's Hyperband procedure, with the three baselines at equal
//! multiply budget — plus the **training-engine throughput sweep** that
//! gates recovery wall-clock: Adam steps/sec of the allocating
//! `loss_and_grad` path vs the workspace engine (`loss_and_grad_ws` /
//! `loss_and_grad_parallel`) over n × chunk × threads.
//!
//! The full-size RMSE grid is `examples/transform_zoo.rs`.

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline, sparse_plus_lowrank_baseline};
use butterfly::butterfly::module::{BpStack, FactorizeLoss};
use butterfly::butterfly::workspace::ParallelTrainer;
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::runtime::bench::{recovery_steps_per_sec, recovery_workload};
use butterfly::transforms::matrices::target_matrix;
use butterfly::transforms::spec::ALL_TRANSFORMS;
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use butterfly::util::timer::{black_box, smoke_mode};
use std::time::Instant;

/// Steps/sec of the allocating path: fresh grad buffers + per-chunk
/// allocations every step, exactly as the pre-workspace `Trial::advance`
/// hot loop behaved. Kept local: this is the historical baseline the
/// sweep compares against, not a configuration anything still ships.
fn steps_per_sec_alloc(loss: &FactorizeLoss, stack: &BpStack, steps: usize) -> f64 {
    // warmup
    let mut grad = stack.zero_grad();
    black_box(loss.loss_and_grad(stack, &mut grad));
    let t0 = Instant::now();
    for _ in 0..steps {
        let mut grad = stack.zero_grad();
        black_box(loss.loss_and_grad(stack, &mut grad));
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn engine_sweep(fast: bool) {
    let ns: &[usize] = if fast { &[64] } else { &[64, 256, 1024] };
    let chunks: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256] };
    let threads: &[usize] = if fast { &[1, 4] } else { &[1, 4, 8] };
    let mut header = vec!["n".to_string(), "chunk".to_string(), "alloc 1T sps".to_string()];
    for &t in threads {
        header.push(format!("ws {t}T sps"));
    }
    header.push("ws/alloc 1T".to_string());
    let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&cols)
        .with_title("fig3 engine: Adam steps/sec, allocating path vs workspace engine");
    for &n in ns {
        let steps = if fast {
            8
        } else {
            match n {
                64 => 60,
                256 => 16,
                _ => 4,
            }
        };
        for &chunk in chunks {
            if chunk > n {
                continue;
            }
            // the shared harness workload (runtime::bench) — same stack
            // and target construction the `bench` CLI's train area pins
            let (stack, loss) = recovery_workload(n, chunk, 7);
            let alloc_sps = steps_per_sec_alloc(&loss, &stack, steps);
            let mut row = vec![n.to_string(), chunk.to_string(), format!("{alloc_sps:.1}")];
            let mut ws1 = 0.0;
            for &t in threads {
                let mut pool = ParallelTrainer::new(n, t);
                let sps = recovery_steps_per_sec(&loss, &stack, &mut pool, steps);
                if t == 1 {
                    ws1 = sps;
                }
                row.push(format!("{sps:.1}"));
            }
            row.push(format!("{:.2}x", ws1 / alloc_sps));
            table.add_row(row);
        }
    }
    println!("{}", table.render());
    println!("acceptance shape: ws 1T ≥ 2x alloc at n = 256 (twiddle hoisting +");
    println!("zero steady-state allocations), near-linear ws scaling to 4T.");
}

/// Scalar-vs-SIMD training throughput: Adam steps/sec of the 1-thread
/// workspace engine under each kernel backend. Single-threaded while the
/// backend is flipped, so the process-wide override is race-free.
fn kernel_sweep(fast: bool) {
    use butterfly::kernels;
    let native = kernels::auto_detect();
    let ns: &[usize] = if fast { &[64] } else { &[64, 256, 1024] };
    let mut table = Table::new(&["n", "scalar sps", &format!("{} sps", native.name()), "speedup"])
        .with_title(format!(
            "fig3 engine: training steps/sec by kernel backend (ws 1T, chunk 64, isa = [{}])",
            kernels::detected_features().join(","),
        ));
    let prev = kernels::active();
    for &n in ns {
        let steps = if fast { 8 } else { if n <= 64 { 60 } else if n <= 256 { 16 } else { 4 } };
        let chunk = 64.min(n);
        let (stack, loss) = recovery_workload(n, chunk, 7);
        let mut sps = [0.0f64; 2];
        for (i, be) in [kernels::Backend::Scalar, native].into_iter().enumerate() {
            kernels::set_active(be);
            let mut pool = ParallelTrainer::new(n, 1);
            sps[i] = recovery_steps_per_sec(&loss, &stack, &mut pool, steps);
        }
        table.add_row(vec![
            n.to_string(),
            format!("{:.1}", sps[0]),
            format!("{:.1}", sps[1]),
            format!("{:.2}x", sps[1] / sps[0]),
        ]);
    }
    kernels::set_active(prev);
    println!("{}", table.render());
}

fn main() {
    let fast = smoke_mode();

    engine_sweep(fast);
    kernel_sweep(fast);

    let ns: &[usize] = if fast { &[8] } else { &[8, 16, 32] };
    let cfg = SchedulerConfig {
        workers: 0,
        max_resource: if fast { 9 } else { 27 },
        eta: 3,
        step_quantum: if fast { 30 } else { 80 },
        seed: 42,
    };
    let mut table = Table::new(&["transform", "N", "butterfly", "sparse", "low-rank", "sparse+lr", "secs"])
        .with_title("Figure 3 (reduced): RMSE at equal multiplication budget");
    for kind in ALL_TRANSFORMS {
        for &n in ns {
            let t0 = Instant::now();
            let job = FactorizeJob::paper(kind, n, 42, 30_000);
            let res = run_job(&job, &cfg, &Metrics::new(), &Registry::new());
            let mut rng = Rng::new(42);
            let target = target_matrix(kind, n, &mut rng);
            let budget = butterfly_budget(n, kind.recommended_depth());
            table.add_row(vec![
                kind.name().to_string(),
                n.to_string(),
                fmt_sci(res.best_rmse),
                fmt_sci(sparse_baseline(&target, budget).rmse),
                fmt_sci(lowrank_baseline(&target, budget).rmse),
                fmt_sci(sparse_plus_lowrank_baseline(&target, budget).rmse),
                format!("{:.1}", t0.elapsed().as_secs_f64()),
            ]);
        }
    }
    println!("{}", table.render());
    println!("paper shape: butterfly ≈ machine precision on the recursive transforms,");
    println!("baselines plateau; legendre partially recovered; randn unrecoverable by all.");
}
