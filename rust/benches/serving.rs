//! E8: serving throughput/latency vs batching window, plus the raw
//! single-thread capacity of the hardened fast multiply (the router's
//! upper bound).

use butterfly::butterfly::closed_form::dft_stack;
use butterfly::butterfly::fast::{FastBp, Workspace};
use butterfly::serving::{BatcherConfig, Router};
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{bench, black_box, BenchConfig};
use std::time::{Duration, Instant};

fn main() {
    let cfg = BenchConfig::from_env();
    let fast_mode = std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let n = 1024usize;
    let requests: usize = if fast_mode { 400 } else { 4000 };
    let clients = 8usize;

    // raw capacity: one worker, batch-32 applies
    let stack = dft_stack(n);
    let fast = FastBp::from_stack(&stack);
    let mut ws = Workspace::new(n);
    let mut re = vec![0.0f32; 32 * n];
    let mut im = vec![0.0f32; 32 * n];
    Rng::new(1).fill_normal(&mut re, 0.0, 1.0);
    let per_batch = bench(&cfg, || {
        fast.apply_complex_batch(black_box(&mut re), black_box(&mut im), 32, &mut ws);
    })
    .median();
    let raw_rps = 32.0 / (per_batch / 1e9);
    println!("raw fast-multiply capacity (1 worker, batch 32): {raw_rps:.0} req/s\n");

    let mut table = Table::new(&["max_batch", "window µs", "replicas", "req/s", "mean batch", "mean latency µs"])
        .with_title(format!("serving bench: N={n}, {clients} clients, {requests} requests"));
    for (max_batch, wait_us, replicas) in
        [(1usize, 0u64, 1usize), (8, 200, 1), (32, 500, 1), (32, 500, 2), (64, 1000, 2)]
    {
        let mut router = Router::new();
        router.install(
            "dft",
            &stack,
            replicas,
            BatcherConfig { max_batch, max_wait: Duration::from_micros(wait_us), queue_cap: 65536 },
        );
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|t| {
                let h = router.handle("dft").unwrap();
                let per = requests / clients;
                std::thread::spawn(move || {
                    let mut rng = Rng::new(t as u64);
                    for _ in 0..per {
                        let mut x = vec![0.0f32; 1024];
                        rng.fill_normal(&mut x, 0.0, 1.0);
                        h.call_real(x).expect("serve");
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = router.shutdown();
        let s = &stats["dft"];
        table.add_row(vec![
            max_batch.to_string(),
            wait_us.to_string(),
            replicas.to_string(),
            format!("{:.0}", s.served as f64 / wall),
            format!("{:.2}", s.served as f64 / s.batches.max(1) as f64),
            format!("{:.0}", s.mean_latency_micros),
        ]);
    }
    println!("{}", table.render());
}
