//! E8: serving throughput/latency vs batching window, plus the raw
//! single-thread capacity of the hardened fast multiply (the router's
//! upper bound).
//!
//! The first table is the batching claim in isolation: vectors/sec of
//! `FastBp::apply_complex_batch_col` at B ∈ {1, 8, 64, 256} (B = 1 is
//! the per-item scalar path the serving worker used before batching).
//! Amortizing gather tables and twiddle loads across lanes must make
//! B = 64 strictly faster per vector than B = 1 for N ≥ 256.
//!
//! The last table is the shared-queue pool's scaling claim: vectors/sec
//! at W ∈ {1, 2, 4, 8} workers draining ONE queue under a fixed offered
//! load (same clients, same request count) — adding workers must not
//! fragment batches the way per-replica queues did.

use butterfly::butterfly::closed_form::{dct_stack, dft_stack, hadamard_stack};
use butterfly::butterfly::fast::{BatchWorkspace, FastBp, Workspace};
use butterfly::kernels;
use butterfly::runtime::bench::{pool_load, scenario_seed};
use butterfly::transforms::fuse::{FuseSpec, FuseStrategy};
use butterfly::transforms::op::{op_ns_per_vec_samples, plan, stack_op, stack_op_fused, LinearOp};
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{bench, black_box, percentile, smoke_mode, BenchConfig};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let cfg = BenchConfig::from_env();
    let fast_mode = smoke_mode();
    let n = 1024usize;
    let requests: usize = if fast_mode { 400 } else { 4000 };
    let clients = 8usize;

    // batched fast-multiply capacity: vectors/sec vs batch size
    let mut btable = Table::new(&["N", "B", "ns/vector", "vectors/s", "speedup vs B=1"])
        .with_title("batched apply capacity (column-major apply_complex_batch_col; B=1 is the per-item path)");
    for nn in [256usize, 1024] {
        let fast = FastBp::from_stack(&dft_stack(nn));
        let mut ws = Workspace::new(nn);
        let mut bws = BatchWorkspace::new();
        let mut per_item_ns = 0.0f64;
        for bsize in [1usize, 8, 64, 256] {
            let mut re = vec![0.0f32; bsize * nn];
            let mut im = vec![0.0f32; bsize * nn];
            Rng::new(nn as u64).fill_normal(&mut re, 0.0, 1.0);
            let per_vec = if bsize == 1 {
                bench(&cfg, || {
                    fast.apply_complex(black_box(&mut re), black_box(&mut im), &mut ws);
                })
                .median()
            } else {
                bench(&cfg, || {
                    fast.apply_complex_batch_col(black_box(&mut re), black_box(&mut im), bsize, &mut bws);
                })
                .median()
                    / bsize as f64
            };
            if bsize == 1 {
                per_item_ns = per_vec;
            }
            btable.add_row(vec![
                nn.to_string(),
                bsize.to_string(),
                format!("{per_vec:.0}"),
                format!("{:.0}", 1e9 / per_vec),
                format!("{:.2}x", per_item_ns / per_vec),
            ]);
        }
    }
    println!("{}", btable.render());

    // scalar vs SIMD kernel backends through the identical batched apply
    // — the microkernel layer's speedup claim in isolation. The bench
    // process is single-threaded here, so flipping the process-wide
    // backend between timed blocks is race-free; it is restored after.
    let native = kernels::auto_detect();
    let mut ktable = Table::new(&["N", "B", "scalar ns/vec", &format!("{} ns/vec", native.name()), "speedup"])
        .with_title(format!(
            "kernel backends, apply_complex_batch_col (native = {}, isa = [{}])",
            native.name(),
            kernels::detected_features().join(","),
        ));
    let prev = kernels::active();
    for nn in [256usize, 1024] {
        let fast = FastBp::from_stack(&dft_stack(nn));
        let mut bws = BatchWorkspace::new();
        for bsize in [8usize, 64] {
            let mut re = vec![0.0f32; bsize * nn];
            let mut im = vec![0.0f32; bsize * nn];
            Rng::new(nn as u64).fill_normal(&mut re, 0.0, 1.0);
            let mut per_vec = [0.0f64; 2];
            for (i, be) in [kernels::Backend::Scalar, native].into_iter().enumerate() {
                kernels::set_active(be);
                per_vec[i] = bench(&cfg, || {
                    fast.apply_complex_batch_col(black_box(&mut re), black_box(&mut im), bsize, &mut bws);
                })
                .median()
                    / bsize as f64;
            }
            ktable.add_row(vec![
                nn.to_string(),
                bsize.to_string(),
                format!("{:.0}", per_vec[0]),
                format!("{:.0}", per_vec[1]),
                format!("{:.2}x", per_vec[0] / per_vec[1]),
            ]);
        }
    }
    kernels::set_active(prev);
    println!("{}", ktable.render());

    // exact closed-form ops vs learned/hardened BP stacks, through the
    // IDENTICAL harness: every op is an Arc<dyn LinearOp> driven by the
    // same column-major apply_batch + OpWorkspace loop the serving
    // worker uses. Real ops run their natural single-plane path (what a
    // real route carries); complex ops run both planes.
    let opn = 1024usize;
    let ops: Vec<(&str, Arc<dyn LinearOp>)> = vec![
        ("dft: exact FFT", plan(TransformKind::Dft, opn)),
        ("dft: BP stack", stack_op("bp-dft", &dft_stack(opn))),
        ("hadamard: exact FWHT", plan(TransformKind::Hadamard, opn)),
        ("hadamard: BP stack", stack_op("bp-hadamard", &hadamard_stack(opn))),
        ("dct: exact fast DCT", plan(TransformKind::Dct, opn)),
        ("convolution: exact circulant", plan(TransformKind::Convolution, opn)),
    ];
    let mut otable = Table::new(&["op", "planes", "flops/apply", "B=1 ns/vec", "B=8 ns/vec", "B=64 ns/vec"])
        .with_title(format!("exact ops vs learned stacks, unified LinearOp harness (N={opn})"));
    // pristine-input restore per apply (the non-unitary circulant would
    // otherwise overflow its own output) lives inside the shared
    // measurement core — the same numbers `bench --json` commits
    let (op_reps, op_iters) = if fast_mode { (1usize, 2usize) } else { (7, 25) };
    for (label, op) in &ops {
        let mut row = vec![
            label.to_string(),
            if op.is_complex() { "2 (complex)".into() } else { "1 (real)".into() },
            op.flops_per_apply().to_string(),
        ];
        for bsize in [1usize, 8, 64] {
            let samples =
                op_ns_per_vec_samples(op.as_ref(), bsize, op_reps, op_iters, bsize as u64 ^ 0xBE7C);
            row.push(format!("{:.0}", percentile(&samples, 50.0)));
        }
        otable.add_row(row);
    }
    println!("{}", otable.render());

    // fused vs unfused: the factor-fusion claim, measured through the
    // same harness. Each closed-form stack serves as log N butterfly
    // stages and as K ∈ {2, 4} fused block-sparse kernels; the trailing
    // columns are the fused/unfused ns/vec ratio (< 1.00x = fusion wins).
    let fstacks: Vec<(&str, butterfly::butterfly::module::BpStack)> =
        vec![("fft", dft_stack(opn)), ("dct2", dct_stack(opn)), ("fwht", hadamard_stack(opn))];
    let mut ftable = Table::new(&[
        "stack",
        "apply path",
        "flops/apply",
        "B=1 ns/vec",
        "B=64 ns/vec",
        "B=1 vs unfused",
        "B=64 vs unfused",
    ])
    .with_title(format!("fused vs unfused butterfly stacks (N={opn}, balanced split)"));
    for (label, stack) in &fstacks {
        let mut variants: Vec<(String, Arc<dyn LinearOp>)> =
            vec![("unfused (log N stages)".into(), stack_op(format!("stack-{label}"), stack))];
        for k in [2usize, 4] {
            variants.push((
                format!("fused k={k}"),
                stack_op_fused(format!("fused-{label}"), stack, &FuseSpec::with_k(k, FuseStrategy::Balanced)),
            ));
        }
        let mut base = [1.0f64; 2];
        for (i, (path, op)) in variants.iter().enumerate() {
            let mut ns = [0.0f64; 2];
            for (j, &bsize) in [1usize, 64].iter().enumerate() {
                let samples =
                    op_ns_per_vec_samples(op.as_ref(), bsize, op_reps, op_iters, bsize as u64 ^ 0xF05E);
                ns[j] = percentile(&samples, 50.0);
            }
            if i == 0 {
                base = ns;
            }
            ftable.add_row(vec![
                label.to_string(),
                path.clone(),
                op.flops_per_apply().to_string(),
                format!("{:.0}", ns[0]),
                format!("{:.0}", ns[1]),
                format!("{:.2}x", ns[0] / base[0]),
                format!("{:.2}x", ns[1] / base[1]),
            ]);
        }
    }
    println!("{}", ftable.render());

    // raw capacity: one worker, batch-32 applies
    let stack = dft_stack(n);
    let fast = FastBp::from_stack(&stack);
    let mut bws = BatchWorkspace::with_capacity(32, n);
    let mut re = vec![0.0f32; 32 * n];
    let mut im = vec![0.0f32; 32 * n];
    Rng::new(1).fill_normal(&mut re, 0.0, 1.0);
    let per_batch = bench(&cfg, || {
        fast.apply_complex_batch_col(black_box(&mut re), black_box(&mut im), 32, &mut bws);
    })
    .median();
    let raw_rps = 32.0 / (per_batch / 1e9);
    println!("raw fast-multiply capacity (1 worker, batch 32): {raw_rps:.0} req/s\n");

    // batching-window sweep at a fixed worker count
    let mut table = Table::new(&["max_batch", "window µs", "workers", "req/s", "mean batch", "mean latency µs"])
        .with_title(format!("serving bench: N={n}, {clients} clients, {requests} requests"));
    for (max_batch, wait_us, workers) in
        [(1usize, 0u64, 1usize), (8, 200, 1), (32, 500, 1), (32, 500, 2), (64, 1000, 2)]
    {
        let (rps, mean_batch, mean_lat) = run_load(&stack, workers, max_batch, wait_us, clients, requests);
        table.add_row(vec![
            max_batch.to_string(),
            wait_us.to_string(),
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{mean_batch:.2}"),
            format!("{mean_lat:.0}"),
        ]);
    }
    println!("{}", table.render());

    // worker-count sweep at FIXED offered load: the shared-queue pool's
    // scaling claim — vectors/sec as W grows, same clients and requests
    let mut wtable = Table::new(&["workers", "vectors/s", "mean batch", "mean latency µs", "scaling vs W=1"])
        .with_title(format!(
            "shared-queue pool scaling: N={n}, {clients} clients, {requests} requests, max_batch=32, window 500µs"
        ));
    let mut base_rps = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let (rps, mean_batch, mean_lat) = run_load(&stack, workers, 32, 500, clients, requests);
        if workers == 1 {
            base_rps = rps;
        }
        wtable.add_row(vec![
            workers.to_string(),
            format!("{rps:.0}"),
            format!("{mean_batch:.2}"),
            format!("{mean_lat:.0}"),
            format!("{:.2}x", rps / base_rps),
        ]);
    }
    println!("{}", wtable.render());
}

/// Drive `requests` total requests from `clients` threads through one
/// route served by a `workers`-wide shared-queue pool; returns
/// (vectors/sec, mean batch, mean latency µs). Thin adapter over the
/// shared `runtime::bench::pool_load` harness — the exact loop the
/// `bench` CLI's serving area commits to `BENCH_serving.json`.
fn run_load(
    stack: &butterfly::butterfly::module::BpStack,
    workers: usize,
    max_batch: usize,
    wait_us: u64,
    clients: usize,
    requests: usize,
) -> (f64, f64, f64) {
    let s = pool_load(
        stack_op("dft", stack),
        workers,
        max_batch,
        Duration::from_micros(wait_us),
        clients,
        requests,
        scenario_seed("benches/serving"),
    );
    (s.vectors_per_sec, s.mean_batch, s.mean_latency_micros)
}
