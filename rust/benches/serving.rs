//! E8: serving throughput/latency vs batching window, plus the raw
//! single-thread capacity of the hardened fast multiply (the router's
//! upper bound).
//!
//! The first table is the batching claim in isolation: vectors/sec of
//! `FastBp::apply_complex_batch_col` at B ∈ {1, 8, 64, 256} (B = 1 is
//! the per-item scalar path the serving worker used before batching).
//! Amortizing gather tables and twiddle loads across lanes must make
//! B = 64 strictly faster per vector than B = 1 for N ≥ 256.

use butterfly::butterfly::closed_form::dft_stack;
use butterfly::butterfly::fast::{BatchWorkspace, FastBp, Workspace};
use butterfly::serving::{BatcherConfig, Router};
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use butterfly::util::timer::{bench, black_box, BenchConfig};
use std::time::{Duration, Instant};

fn main() {
    let cfg = BenchConfig::from_env();
    let fast_mode = std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let n = 1024usize;
    let requests: usize = if fast_mode { 400 } else { 4000 };
    let clients = 8usize;

    // batched fast-multiply capacity: vectors/sec vs batch size
    let mut btable = Table::new(&["N", "B", "ns/vector", "vectors/s", "speedup vs B=1"])
        .with_title("batched apply capacity (column-major apply_complex_batch_col; B=1 is the per-item path)");
    for nn in [256usize, 1024] {
        let fast = FastBp::from_stack(&dft_stack(nn));
        let mut ws = Workspace::new(nn);
        let mut bws = BatchWorkspace::new();
        let mut per_item_ns = 0.0f64;
        for bsize in [1usize, 8, 64, 256] {
            let mut re = vec![0.0f32; bsize * nn];
            let mut im = vec![0.0f32; bsize * nn];
            Rng::new(nn as u64).fill_normal(&mut re, 0.0, 1.0);
            let per_vec = if bsize == 1 {
                bench(&cfg, || {
                    fast.apply_complex(black_box(&mut re), black_box(&mut im), &mut ws);
                })
                .median()
            } else {
                bench(&cfg, || {
                    fast.apply_complex_batch_col(black_box(&mut re), black_box(&mut im), bsize, &mut bws);
                })
                .median()
                    / bsize as f64
            };
            if bsize == 1 {
                per_item_ns = per_vec;
            }
            btable.add_row(vec![
                nn.to_string(),
                bsize.to_string(),
                format!("{per_vec:.0}"),
                format!("{:.0}", 1e9 / per_vec),
                format!("{:.2}x", per_item_ns / per_vec),
            ]);
        }
    }
    println!("{}", btable.render());

    // raw capacity: one worker, batch-32 applies
    let stack = dft_stack(n);
    let fast = FastBp::from_stack(&stack);
    let mut bws = BatchWorkspace::with_capacity(32, n);
    let mut re = vec![0.0f32; 32 * n];
    let mut im = vec![0.0f32; 32 * n];
    Rng::new(1).fill_normal(&mut re, 0.0, 1.0);
    let per_batch = bench(&cfg, || {
        fast.apply_complex_batch_col(black_box(&mut re), black_box(&mut im), 32, &mut bws);
    })
    .median();
    let raw_rps = 32.0 / (per_batch / 1e9);
    println!("raw fast-multiply capacity (1 worker, batch 32): {raw_rps:.0} req/s\n");

    let mut table = Table::new(&["max_batch", "window µs", "replicas", "req/s", "mean batch", "mean latency µs"])
        .with_title(format!("serving bench: N={n}, {clients} clients, {requests} requests"));
    for (max_batch, wait_us, replicas) in
        [(1usize, 0u64, 1usize), (8, 200, 1), (32, 500, 1), (32, 500, 2), (64, 1000, 2)]
    {
        let mut router = Router::new();
        router.install(
            "dft",
            &stack,
            replicas,
            BatcherConfig { max_batch, max_wait: Duration::from_micros(wait_us), queue_cap: 65536 },
        );
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|t| {
                let h = router.handle("dft").unwrap();
                let per = requests / clients;
                std::thread::spawn(move || {
                    let mut rng = Rng::new(t as u64);
                    for _ in 0..per {
                        let mut x = vec![0.0f32; 1024];
                        rng.fill_normal(&mut x, 0.0, 1.0);
                        h.call_real(x).expect("serve");
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = router.shutdown();
        let s = &stats["dft"];
        table.add_row(vec![
            max_batch.to_string(),
            wait_us.to_string(),
            replicas.to_string(),
            format!("{:.0}", s.served as f64 / wall),
            format!("{:.2}", s.served as f64 / s.batches.max(1) as f64),
            format!("{:.0}", s.mean_latency_micros),
        ]);
    }
    println!("{}", table.render());
}
