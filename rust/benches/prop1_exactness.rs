//! Proposition 1 exactness + cost: closed-form BP/BP² stacks vs their
//! dense targets across N, with construction and fast-apply timings.

use butterfly::butterfly::closed_form::{convolution_stack, dct_stack, dft_stack, dst_stack, hadamard_stack};
use butterfly::butterfly::fast::{FastBp, Workspace};
use butterfly::linalg::dense::{CMat, Mat};
use butterfly::transforms::matrices;
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use butterfly::util::timer::{bench, black_box, smoke_mode, BenchConfig};

fn real_plane_rmse(m: &CMat, t: &Mat) -> f64 {
    let n = m.rows;
    let mut acc = 0.0f64;
    for i in 0..n * n {
        let d = (m.re[i] - t.data[i]) as f64;
        acc += d * d;
    }
    (acc / (n * n) as f64).sqrt()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let fast_mode = smoke_mode();
    let ns: &[usize] = if fast_mode { &[64] } else { &[64, 256, 1024] };
    let mut table = Table::new(&["transform", "class", "N", "rmse", "apply ns"])
        .with_title("Proposition 1: closed-form factorizations (exactness + O(N log N) apply)");
    for &n in ns {
        let mut rng = Rng::new(1);
        let mut h = vec![0.0f32; n];
        rng.fill_normal(&mut h, 0.0, (1.0 / n as f64).sqrt() as f32);
        let rows: Vec<(&str, &str, _, f64)> = vec![
            ("dft", "(BP)^1", dft_stack(n), dft_stack(n).to_matrix().rmse_to(&matrices::dft_matrix(n))),
            (
                "hadamard",
                "(BP)^1",
                hadamard_stack(n),
                hadamard_stack(n).to_matrix().rmse_to(&matrices::hadamard_matrix(n).to_cmat()),
            ),
            ("dct", "(BP)^2 ℜ", dct_stack(n), real_plane_rmse(&dct_stack(n).to_matrix(), &matrices::dct_matrix(n))),
            ("dst", "(BP)^2 ℜ", dst_stack(n), real_plane_rmse(&dst_stack(n).to_matrix(), &matrices::dst_matrix(n))),
            (
                "convolution",
                "(BP)^2",
                convolution_stack(&h),
                convolution_stack(&h).to_matrix().rmse_to(&matrices::circulant_matrix(&h).to_cmat()),
            ),
        ];
        for (name, class, stack, rmse) in rows {
            let fast = FastBp::from_stack(&stack);
            let mut ws = Workspace::new(n);
            let mut re = vec![0.0f32; n];
            let mut im = vec![0.0f32; n];
            rng.fill_normal(&mut re, 0.0, 1.0);
            let apply = bench(&cfg, || {
                fast.apply_complex(black_box(&mut re), black_box(&mut im), &mut ws);
            })
            .median();
            table.add_row(vec![
                name.to_string(),
                class.to_string(),
                n.to_string(),
                fmt_sci(rmse),
                format!("{apply:.0}"),
            ]);
        }
    }
    println!("{}", table.render());
}
