//! Table 2 (paper §4.2): a residual CNN ± {nothing, FC, BPBP} inserted
//! before the classifier head, on the synthetic CIFAR-gray dataset.
//!
//! ```text
//! cargo run --release --example resnet_butterfly -- --epochs 2 --train-samples 800
//! ```
//!
//! The backbone is a compact 3-stage ResNet (DESIGN.md §5 documents the
//! ResNet18 → compact substitution); the experiment's claim is the
//! relative delta between the three pre-classifier variants, which is
//! preserved.

use butterfly::cli::Args;
use butterfly::data::batcher::BatchIter;
use butterfly::data::synth::{generate, DatasetKind, CLASSES};
use butterfly::nn::convnet::{PreClassifier, SmallResNet};
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use std::time::Instant;

fn main() {
    let args = Args::from_env_no_command().unwrap_or_default();
    let epochs = args.usize_or("epochs", 2).unwrap();
    let train_n = args.usize_or("train-samples", 800).unwrap();
    let test_n = args.usize_or("test-samples", 300).unwrap();
    let width = args.usize_or("width", 8).unwrap();
    let blocks = args.usize_or("blocks", 1).unwrap();
    let lr = args.f64_or("lr", 0.01).unwrap() as f32;

    println!("== resnet_butterfly: Table 2 (pre-classifier {{none, fc, bpbp}}) ==");
    let train = generate(DatasetKind::CifarGray, train_n, 42);
    let test = generate(DatasetKind::CifarGray, test_n, 43);

    let mut table = Table::new(&["last layer", "test acc", "params", "Δ params"])
        .with_title("Table 2 analogue (compact ResNet, synthetic CIFAR-gray)");
    let mut base_params = 0usize;
    for pre in [PreClassifier::None, PreClassifier::Fc, PreClassifier::Bpbp] {
        let t0 = Instant::now();
        let mut rng = Rng::new(7);
        let mut net = SmallResNet::new(32, CLASSES, width, blocks, pre, &mut rng);
        if pre == PreClassifier::None {
            base_params = net.param_count();
        }
        let mut data_rng = Rng::new(11);
        for epoch in 0..epochs {
            let mut iter = BatchIter::new(&train, 25, &mut data_rng);
            let mut loss_sum = 0.0f64;
            let mut nb = 0usize;
            while let Some((x, y)) = iter.next_batch() {
                let (loss, _) = net.train_step(&x, &y, lr, 0.9, 2e-4);
                loss_sum += loss as f64;
                nb += 1;
            }
            eprintln!("  [{}] epoch {epoch}: mean loss {:.4}", pre.name(), loss_sum / nb as f64);
        }
        let acc = net.evaluate(&test, 50);
        eprintln!("  [{}] test acc {acc:.3} ({:.1}s)", pre.name(), t0.elapsed().as_secs_f64());
        table.add_row(vec![
            pre.name().to_string(),
            format!("{acc:.3}"),
            net.param_count().to_string(),
            format!("+{}", net.param_count() - base_params),
        ]);
    }
    println!("{}", table.render());
    println!("(paper: None 93.58, FC 93.89, BPBP 94.01 on real CIFAR-10/ResNet18 —");
    println!(" the claim reproduced here is the ordering and the tiny BPBP parameter delta)");
}
