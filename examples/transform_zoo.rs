//! The Figure-3 grid (paper §4.1): recover all eight transforms over a
//! range of N, comparing the butterfly parameterization against the
//! sparse / low-rank / sparse+low-rank baselines at equal multiplication
//! budget.
//!
//! ```text
//! cargo run --release --example transform_zoo -- --max-n 64
//! cargo run --release --example transform_zoo -- --max-n 1024 --max-resource 81   # full (slow)
//! ```

use butterfly::baselines::{butterfly_budget, lowrank_baseline, sparse_baseline, sparse_plus_lowrank_baseline};
use butterfly::cli::Args;
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::transforms::matrices::target_matrix;
use butterfly::transforms::op::{plan_with_rng, OpWorkspace};
use butterfly::transforms::spec::ALL_TRANSFORMS;
use butterfly::util::rng::Rng;
use butterfly::util::table::{fmt_sci, Table};
use std::time::Instant;

fn main() {
    let args = Args::from_env_no_command().unwrap_or_default();
    let max_n = args.usize_or("max-n", 64).unwrap();
    let cfg = SchedulerConfig {
        workers: args.usize_or("workers", 0).unwrap(),
        max_resource: args.usize_or("max-resource", 27).unwrap(),
        eta: 3,
        step_quantum: args.usize_or("quantum", 60).unwrap(),
        seed: args.u64_or("seed", 42).unwrap(),
    };
    let mut ns = vec![];
    let mut n = 8;
    while n <= max_n {
        ns.push(n);
        n *= 2;
    }

    let mut grid = Table::new(
        &std::iter::once("transform".to_string())
            .chain(ns.iter().map(|n| format!("N={n}")))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>(),
    )
    .with_title("Figure 3: butterfly recovery RMSE (early stop at 1e-4)");
    let mut base_table = Table::new(&["transform", "N", "butterfly", "sparse", "low-rank", "sparse+lr"])
        .with_title("Figure 3 baselines @ equal multiply budget (largest N)");

    for kind in ALL_TRANSFORMS {
        let mut row = vec![kind.name().to_string()];
        let mut last_rmse = f64::NAN;
        for &n in &ns {
            let t0 = Instant::now();
            let job = FactorizeJob::paper(kind, n, cfg.seed, 50_000);
            let res = run_job(&job, &cfg, &Metrics::new(), &Registry::new());
            last_rmse = res.best_rmse;
            row.push(fmt_sci(res.best_rmse));
            eprintln!(
                "  {} N={n}: rmse {} ({} trials, {:.1}s){}",
                kind.name(),
                fmt_sci(res.best_rmse),
                res.trials_run,
                t0.elapsed().as_secs_f64(),
                if res.reached_target { "  ✓ machine precision" } else { "" }
            );
        }
        grid.add_row(row);
        // baselines at the largest N for this transform
        let n = *ns.last().unwrap();
        let mut rng = Rng::new(cfg.seed);
        let target = target_matrix(kind, n, &mut rng);
        let budget = butterfly_budget(n, kind.recommended_depth());
        base_table.add_row(vec![
            kind.name().to_string(),
            n.to_string(),
            fmt_sci(last_rmse),
            fmt_sci(sparse_baseline(&target, budget).rmse),
            fmt_sci(lowrank_baseline(&target, budget).rmse),
            fmt_sci(sparse_plus_lowrank_baseline(&target, budget).rmse),
        ]);
    }
    println!("{}", grid.render());
    println!("{}", base_table.render());

    // The unified factory: every kind in the zoo resolves to one
    // Arc<dyn LinearOp> — the closed-form fast algorithm where the paper
    // gives one, the dense reference otherwise — and each op is checked
    // here against its dense specification on random probes (the same
    // conformance the serving pool relies on).
    let n = *ns.last().unwrap();
    let batch = 8usize;
    let mut ws = OpWorkspace::new();
    let mut op_table = Table::new(&["transform", "op", "planes", "flops/apply", "probe rmse vs dense"])
        .with_title(format!("unified LinearOp factory (plan(kind, {n})) vs dense specs"));
    for kind in ALL_TRANSFORMS {
        let op = plan_with_rng(kind, n, &mut Rng::new(cfg.seed));
        let dense = target_matrix(kind, n, &mut Rng::new(cfg.seed));
        let mut rng = Rng::new(99);
        let mut re = vec![0.0f32; batch * n];
        let mut im = vec![0.0f32; batch * n];
        rng.fill_normal(&mut re, 0.0, 1.0);
        rng.fill_normal(&mut im, 0.0, 1.0);
        let (want_re, want_im) = dense.matvec_batch_planar(&re, &im, batch);
        // column-major copy, one batched apply, rmse against the spec
        let mut cre = vec![0.0f32; batch * n];
        let mut cim = vec![0.0f32; batch * n];
        for b in 0..batch {
            for i in 0..n {
                cre[i * batch + b] = re[b * n + i];
                cim[i * batch + b] = im[b * n + i];
            }
        }
        op.apply_batch(&mut cre, &mut cim, batch, &mut ws);
        let mut acc = 0.0f64;
        for b in 0..batch {
            for i in 0..n {
                let dr = (cre[i * batch + b] - want_re[b * n + i]) as f64;
                let di = (cim[i * batch + b] - want_im[b * n + i]) as f64;
                acc += dr * dr + di * di;
            }
        }
        let rmse = (acc / (batch * n) as f64).sqrt();
        op_table.add_row(vec![
            kind.name().to_string(),
            op.name().to_string(),
            if op.is_complex() { "2".into() } else { "1".into() },
            op.flops_per_apply().to_string(),
            fmt_sci(rmse),
        ]);
    }
    println!("{}", op_table.render());
}
