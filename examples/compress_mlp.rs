//! **The end-to-end driver** (Table 1, paper §4.2): train the single
//! hidden layer benchmark with a BPBP structured hidden layer — with the
//! training step running as an AOT-compiled XLA computation that
//! contains the Pallas butterfly kernels, driven entirely from Rust.
//!
//! This proves the three layers compose: L1 (Pallas level kernel) lowers
//! into L2 (JAX train-step graph), which L3 (this Rust binary) loads via
//! PJRT and drives with Rust-generated data. Python is not running.
//!
//! ```text
//! cargo run --release --example compress_mlp [-- --steps 400 --dataset cifar10-gray]
//! ```
//!
//! Also trains the *unstructured dense* baseline (native Rust backprop)
//! at the same budget and prints the Table-1-style comparison with
//! parameter counts / compression factors. Results land in
//! EXPERIMENTS.md §E2.

use butterfly::cli::Args;
use butterfly::data::batcher::BatchIter;
use butterfly::data::synth::{generate, DatasetKind, CLASSES, DIM};
use butterfly::nn::mlp::{train_mlp, HiddenKind, TrainConfig};
use butterfly::runtime::engine::{Engine, XlaEngine};
use butterfly::runtime::tensor::Tensor;
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use std::time::Instant;

fn main() {
    let args = Args::from_env_no_command().unwrap_or_default();
    let steps = args.usize_or("steps", 400).unwrap();
    let train_n = args.usize_or("train-samples", 2000).unwrap();
    let test_n = args.usize_or("test-samples", 500).unwrap();
    // the XLA graph's tied-twiddle gradient accumulation order makes it
    // diverge above ~0.02 where the native path still converges; 0.01 is
    // stable and reaches the dense baseline's accuracy
    let lr = args.f64_or("lr", 0.01).unwrap() as f32;
    let baseline_lr = args.f64_or("baseline-lr", 0.05).unwrap() as f32;
    let dataset = DatasetKind::parse(args.get_or("dataset", "cifar10-gray")).expect("dataset");
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    println!("== compress_mlp: Table 1 end-to-end (XLA + Pallas hot path) ==");
    println!("dataset: {} ({} train / {} test, dim {DIM}, {CLASSES} classes)", dataset.name(), train_n, test_n);

    let train = generate(dataset, train_n, 42);
    let test = generate(dataset, test_n, 43);

    // ---------------- BPBP via the AOT XLA engine ----------------
    let mut xla = match XlaEngine::open(&artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("cannot open artifacts/ ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let train_entry = "mlp_train_n1024_b50";
    let eval_entry = "mlp_eval_n1024_b100";
    assert!(xla.has_entry(train_entry), "{train_entry} missing — rebuild artifacts");

    // theta layout must match python/compile/model.py mlp_slices
    let theta0 = init_mlp_theta(DIM, CLASSES, 7);
    let p = theta0.len();
    println!("BPBP theta: {p} scalars (hidden trainable ≈ {} after masks)", 2 * (4 * DIM - 4) + DIM);
    let mut theta = Tensor::new(vec![p], theta0);
    let mut vel = Tensor::zeros(vec![p]);
    let mask = Tensor::new(vec![p], mlp_mask(DIM, CLASSES));
    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let mut losses = Vec::new();
    let mut step = 0usize;
    'train: loop {
        let mut iter = BatchIter::new(&train, 50, &mut rng);
        while let Some((x, y)) = iter.next_batch() {
            if y.len() < 50 {
                continue; // entry is compiled for batch 50 exactly
            }
            let y_onehot = onehot(&y, CLASSES);
            let out = xla
                .run(
                    train_entry,
                    &[
                        theta.clone(),
                        vel.clone(),
                        Tensor::new(vec![50, DIM], x),
                        Tensor::new(vec![50, CLASSES], y_onehot),
                        Tensor::new(vec![1], vec![lr]),
                        mask.clone(),
                    ],
                )
                .expect("xla train step");
            theta = out[0].clone();
            vel = out[1].clone();
            losses.push(out[2].data[0]);
            step += 1;
            if step % 50 == 0 {
                println!("  step {step:4}: loss {:.4} acc {:.3}", out[2].data[0], out[3].data[0]);
            }
            if step >= steps {
                break 'train;
            }
        }
    }
    let bpbp_wall = t0.elapsed().as_secs_f64();
    // eval through the AOT eval graph, batch 100
    let mut correct_w = 0.0f64;
    let mut batches = 0usize;
    let mut i = 0;
    while i + 100 <= test.len() {
        let x = test.x[i * DIM..(i + 100) * DIM].to_vec();
        let y_onehot = onehot(&test.y[i..i + 100], CLASSES);
        let out = xla
            .run(eval_entry, &[theta.clone(), Tensor::new(vec![100, DIM], x), Tensor::new(vec![100, CLASSES], y_onehot)])
            .expect("xla eval");
        correct_w += out[1].data[0] as f64;
        batches += 1;
        i += 100;
    }
    let bpbp_acc = (correct_w / batches as f64) as f32;
    println!(
        "BPBP (XLA/Pallas): test acc {:.3} after {} steps in {:.1}s (loss {:.3} → {:.3})",
        bpbp_acc,
        step,
        bpbp_wall,
        losses.first().unwrap(),
        losses.last().unwrap()
    );

    // ---------------- dense + circulant baselines (native) ----------------
    let epochs = (steps * 50 / train.len()).max(1);
    let cfg = TrainConfig { epochs, batch: 50, lr: baseline_lr, ..Default::default() };
    println!("training native baselines ({} epochs)…", cfg.epochs);
    let dense = train_mlp(HiddenKind::Dense, &train, &test, &cfg);
    let bpbp_native = train_mlp(HiddenKind::BpbpReal, &train, &test, &cfg);
    let circ = train_mlp(HiddenKind::Circulant, &train, &test, &cfg);
    let lowrank = train_mlp(HiddenKind::LowRank { rank: 8 }, &train, &test, &cfg);

    let dense_total = dense.total_params as f64;
    let bpbp_params = 2 * (4 * DIM - 4) + DIM + CLASSES * DIM + CLASSES;
    let mut table = Table::new(&["method", "test acc", "params", "compression"])
        .with_title(format!("Table 1 analogue — {}", dataset.name()));
    table.add_row(vec![
        "BPBP real (XLA+Pallas)".into(),
        format!("{:.3}", bpbp_acc),
        format!("{bpbp_params}"),
        format!("{:.1}x", dense_total / bpbp_params as f64),
    ]);
    for r in [&dense, &bpbp_native, &circ, &lowrank] {
        table.add_row(vec![
            r.kind.name(),
            format!("{:.3}", r.test_acc),
            format!("{}", r.total_params),
            format!("{:.1}x", dense_total / r.total_params as f64),
        ]);
    }
    println!("{}", table.render());
    println!("(loss curve: first 5 {:?} … last 5 {:?})", &losses[..5.min(losses.len())], &losses[losses.len().saturating_sub(5)..]);
}

fn onehot(y: &[u8], classes: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; y.len() * classes];
    for (i, &c) in y.iter().enumerate() {
        out[i * classes + c as usize] = 1.0;
    }
    out
}

/// Mirror of python `model.init_mlp_theta` (layout contract), but with
/// this library's RNG: BPBP real, fixed bit-reversal, zero bias, uniform
/// head.
fn init_mlp_theta(n: usize, classes: usize, seed: u64) -> Vec<f32> {
    use butterfly::butterfly::params::{BpParams, Field, InitScheme, PermTying, TwiddleTying};
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..2 {
        let mut p = BpParams::init(
            n,
            Field::Real,
            TwiddleTying::Factor,
            PermTying::Untied,
            InitScheme::OrthogonalLike,
            &mut rng,
        );
        p.fix_bit_reversal();
        out.extend_from_slice(&p.data);
    }
    out.extend(std::iter::repeat(0.0f32).take(n)); // bias
    let bound = (6.0 / n as f64).sqrt() as f32;
    let mut w = vec![0.0f32; classes * n];
    rng.fill_uniform(&mut w, -bound, bound);
    out.extend_from_slice(&w);
    out.extend(std::iter::repeat(0.0f32).take(classes)); // head bias
    out
}

/// Trainable mask in theta layout (mirror of python
/// `model.mlp_trainable_mask`): module masks from `BpParams` (imag
/// planes + fixed-perm logits frozen), everything else trainable.
fn mlp_mask(n: usize, classes: usize) -> Vec<f32> {
    use butterfly::butterfly::params::{BpParams, Field, PermTying, TwiddleTying};
    let mut p = BpParams::new(n, Field::Real, TwiddleTying::Factor, PermTying::Untied);
    p.fix_bit_reversal();
    let module_mask = p.trainable_mask();
    let mut out = Vec::new();
    out.extend_from_slice(&module_mask);
    out.extend_from_slice(&module_mask);
    out.extend(std::iter::repeat(1.0f32).take(n + classes * n + classes));
    out
}
