//! Serving demo (systems extension of Figure 4): install several
//! transforms behind the router through the unified `LinearOp` API —
//! exact closed-form ops from the `plan()` factory and hardened BP
//! stacks through `stack_op()`, side by side on the identical
//! pool/batcher path — and measure latency/throughput as a function of
//! the batching window, plus a pipelined `submit()` burst.
//!
//! ```text
//! cargo run --release --example serve_transforms -- --n 1024 --requests 4000
//! ```

use butterfly::butterfly::closed_form::dft_stack;
use butterfly::cli::Args;
use butterfly::serving::{BatcherConfig, Router};
use butterfly::transforms::op::{plan, stack_op, LinearOp, OpWorkspace};
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;
use butterfly::util::table::Table;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env_no_command().unwrap_or_default();
    let n = args.usize_or("n", 1024).unwrap();
    let requests = args.usize_or("requests", 4000).unwrap();
    let clients = args.usize_or("clients", 8).unwrap();

    println!("== serve_transforms: one LinearOp API from plans to the serving pool ==");

    // Direct batched-apply capacity: what one worker gets from coalescing
    // a batch into a single column-major apply_batch call (the same
    // trait entry point the service worker below uses), for an exact op
    // and a hardened stack of the same transform.
    let ops: Vec<(&str, Arc<dyn LinearOp>)> = vec![
        ("dft (exact FFT)", plan(TransformKind::Dft, n)),
        ("dft (BP stack)", stack_op("bp-dft", &dft_stack(n))),
        ("dct (exact fast DCT)", plan(TransformKind::Dct, n)),
    ];
    let mut ws = OpWorkspace::new();
    let mut cap = Table::new(&["op", "B", "vectors/s (1 worker)"])
        .with_title(format!("direct LinearOp::apply_batch capacity, N={n}"));
    for (label, op) in &ops {
        for bsize in [1usize, 8, 64, 256] {
            let mut re = vec![0.0f32; bsize * n];
            let mut im = vec![0.0f32; bsize * n];
            Rng::new(9).fill_normal(&mut re, 0.0, 1.0);
            let reps = (2048 / bsize).max(4);
            let t0 = Instant::now();
            for _ in 0..reps {
                if op.is_complex() {
                    op.apply_batch(&mut re, &mut im, bsize, &mut ws);
                } else {
                    // real ops carry a single plane, as on a real route
                    op.apply_batch(&mut re, &mut [], bsize, &mut ws);
                }
            }
            let per_vec = t0.elapsed().as_secs_f64() / (reps * bsize) as f64;
            cap.add_row(vec![label.to_string(), bsize.to_string(), format!("{:.0}", 1.0 / per_vec)]);
        }
    }
    println!("{}", cap.render());

    let mut table = Table::new(&["max_batch", "max_wait", "req/s", "mean batch", "p-mean latency µs"])
        .with_title(format!(
            "serving sweep (N={n}, {clients} clients, {requests} requests, dft pool: 2 workers, 1 shared queue)"
        ));
    for (max_batch, wait_us) in [(1usize, 0u64), (8, 200), (32, 500), (64, 1000)] {
        let mut router = Router::new();
        let cfg = BatcherConfig {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
            queue_cap: 16384,
        };
        // learned-stack route and exact-op routes behind one router
        router.install("dft", stack_op("dft", &dft_stack(n)), 2, cfg.clone());
        router.install("dct", plan(TransformKind::Dct, n), 1, cfg.clone());
        router.install("conv", plan(TransformKind::Convolution, n), 1, cfg);
        let t0 = Instant::now();
        let threads: Vec<_> = (0..clients)
            .map(|t| {
                let handle = router.handle("dft").unwrap();
                let per = requests / clients;
                std::thread::spawn(move || {
                    let mut rng = Rng::new(50 + t as u64);
                    for _ in 0..per {
                        let mut x = vec![0.0f32; n];
                        rng.fill_normal(&mut x, 0.0, 1.0);
                        handle.call_real(x).expect("serve");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = router.stats();
        let s = &stats["dft"];
        table.add_row(vec![
            max_batch.to_string(),
            format!("{wait_us}µs"),
            format!("{:.0}", s.served as f64 / wall),
            format!("{:.2}", s.mean_batch),
            format!("{:.0}", s.mean_latency_micros),
        ]);
        router.shutdown();
    }
    println!("{}", table.render());
    println!("(larger windows trade latency for batching efficiency — the standard serving knob)");

    // Real routes carry ONE plane: a call_real against the exact DCT op
    // never allocates or queues an imaginary vector.
    let mut router = Router::new();
    router.install("dct", plan(TransformKind::Dct, n), 2, BatcherConfig::default());
    let h = router.handle("dct").unwrap();
    assert!(!h.is_complex());
    let t0 = Instant::now();
    let mut rng = Rng::new(13);
    for _ in 0..512 {
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x, 0.0, 1.0);
        h.call_real(x).expect("dct");
    }
    println!(
        "real route (dct, single plane end to end): 512 calls in {:.1} ms\n",
        t0.elapsed().as_secs_f64() * 1e3
    );
    router.shutdown();

    // Pipelined clients: submit() enqueues without blocking, so one
    // client can keep a whole batch window full by itself — the tickets
    // are then redeemed in order.
    let mut router = Router::new();
    router.install(
        "dft",
        plan(TransformKind::Dft, n),
        4,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_micros(500), queue_cap: 16384 },
    );
    let handle = router.handle("dft").unwrap();
    let burst = 256usize;
    let mut rng = Rng::new(77);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..burst)
        .map(|_| {
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x, 0.0, 1.0);
            handle.submit(x, vec![0.0; n]).expect("submit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("reply");
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = router.stats().remove("dft").unwrap();
    println!(
        "pipelined burst: {burst} submits from 1 client → {:.0} req/s, mean batch {:.1} (vs 1.0 for sync call())",
        burst as f64 / wall,
        s.mean_batch
    );
    router.shutdown();
}
