//! Quickstart: learn the FFT in a few seconds.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Runs one Hyperband-coordinated factorization job on the N=16 DFT,
//! prints the recovered RMSE, hardens the learned permutation, and
//! checks the resulting O(N log N) fast multiply against this library's
//! radix-2 FFT.

use butterfly::butterfly::fast::{FastBp, Workspace};
use butterfly::butterfly::permutation::{hard_perm_table, RelaxedPerm};
use butterfly::coordinator::{run_job, FactorizeJob, Metrics, Registry, SchedulerConfig};
use butterfly::runtime::engine::unpack_stack;
use butterfly::transforms::fast::{bit_reversal_table, fft_unitary};
use butterfly::transforms::spec::TransformKind;
use butterfly::util::rng::Rng;

fn main() {
    let n = 16;
    println!("learning a fast algorithm for the {n}-point DFT…");
    let job = FactorizeJob::paper(TransformKind::Dft, n, 42, 30_000);
    let cfg = SchedulerConfig { max_resource: 27, step_quantum: 120, ..Default::default() };
    let metrics = Metrics::new();
    let registry = Registry::new();
    let res = run_job(&job, &cfg, &metrics, &registry);

    println!("best RMSE        : {:.2e}", res.best_rmse);
    println!("machine precision: {}", if res.reached_target { "yes (< 1e-4)" } else { "not yet (try more steps)" });
    println!("best lr          : {:.4} ({:?} logits)", res.best_config.lr, res.best_config.perm_tying);
    println!("gate confidence  : {:.4} (paper reports ≥ 0.99)", res.perm_confidence);
    println!("coordinator      : {}", metrics.snapshot());

    // install the learned parameters and inspect the discovered algorithm
    let stack = unpack_stack(n, job.depth, &res.best_theta);
    let choices = RelaxedPerm::harden(&stack.modules[0].params);
    let table = hard_perm_table(n, &choices);
    let bitrev = bit_reversal_table(n);
    println!("hardened permutation: {table:?}");
    println!("  (bit-reversal would be {bitrev:?})");
    if table == bitrev {
        println!("  → recovered the Cooley–Tukey bit-reversal exactly!");
    } else {
        println!("  → an unconventional permutation (the paper finds these too)");
    }

    // the learned fast multiply vs the FFT
    let fast = FastBp::from_stack(&stack);
    let mut ws = Workspace::new(n);
    let mut rng = Rng::new(5);
    let mut re = vec![0.0f32; n];
    let mut im = vec![0.0f32; n];
    rng.fill_normal(&mut re, 0.0, 1.0);
    let x: Vec<butterfly::linalg::complex::Cpx> =
        re.iter().map(|&r| butterfly::linalg::complex::Cpx::real(r)).collect();
    let want = fft_unitary(&x);
    fast.apply_complex(&mut re, &mut im, &mut ws);
    let mut worst = 0.0f32;
    for i in 0..n {
        worst = worst.max((re[i] - want[i].re).abs()).max((im[i] - want[i].im).abs());
    }
    println!("learned multiply vs radix-2 FFT: max abs diff {worst:.2e}");
    println!("fast multiply cost: {} flops vs {} for GEMV", fast.flops_per_apply(), 8 * n * n);
}
