"""Layer 2: the JAX model — BP stacks, the factorization objective with a
fused Adam step, and the Table-1 compression MLP with a fused
momentum-SGD step. All entry points operate on ONE flat ``theta`` vector
whose layout matches ``rust/src/butterfly/params.rs`` exactly, so the
Rust coordinator can move parameters between the native and XLA engines
freely (see ``rust/src/runtime/engine.rs`` for the contract).

Per-module layout over ``N = 2^L``::

    [ level-0 twiddle [2, 1, 2, 2] | level-1 [2, 2, 2, 2] | …
      | level-(L−1) [2, 2^{L−1}, 2, 2] | logits [L, 3] ]

(planar re/im, factor-tied twiddles, untied logits). Stack theta =
concatenation of its modules.

Python runs ONCE at build time: ``aot.py`` lowers these functions to HLO
text that the Rust runtime loads. Nothing here runs at serve time.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.butterfly import butterfly_level
from .kernels.ref import bp_module_ref, butterfly_level_ref

# ---------------------------------------------------------------------
# theta packing
# ---------------------------------------------------------------------


def levels_of(n: int) -> int:
    l = int(math.log2(n))
    assert 1 << l == n, f"n must be a power of two, got {n}"
    return l


def module_len(n: int) -> int:
    """Flat scalar count of one BP module (== BpParams::data len)."""
    L = levels_of(n)
    return 8 * (n - 1) + 3 * L


def theta_len(n: int, depth: int) -> int:
    return depth * module_len(n)


def unpack_module(theta_mod, n: int):
    """Split one module's flat slice into per-level twiddles + logits."""
    L = levels_of(n)
    levels = []
    off = 0
    for l in range(L):
        u = 1 << l
        seg = theta_mod[off : off + 2 * u * 4].reshape(2, u, 2, 2)
        levels.append((seg[0], seg[1]))
        off += 2 * u * 4
    logits = theta_mod[off : off + 3 * L].reshape(L, 3)
    return levels, logits


def bp_apply(theta, x_re, x_im, n: int, depth: int, use_pallas: bool = True):
    """Apply a depth-``depth`` BP stack to a planar batch ``[B, N]``."""
    ml = module_len(n)
    level_fn = butterfly_level if use_pallas else butterfly_level_ref
    for d in range(depth):
        levels, logits = unpack_module(theta[d * ml : (d + 1) * ml], n)
        x_re, x_im = bp_module_ref(x_re, x_im, levels, logits, n, use_level=level_fn)
    return x_re, x_im


def bp_apply_packed(theta, x, n: int, depth: int, use_pallas: bool = True):
    """Entry-point shape: ``x [2, B, N] → y [2, B, N]``."""
    y_re, y_im = bp_apply(theta, x[0], x[1], n, depth, use_pallas)
    return jnp.stack([y_re, y_im])


# ---------------------------------------------------------------------
# factorization objective (paper eq. (4)) + fused Adam step
# ---------------------------------------------------------------------


def factorize_loss(theta, target, n: int, depth: int, use_pallas: bool = True):
    """``(1/N²)·‖T − M‖_F²`` via streaming identity rows: applying the
    stack to identity rows yields ``Mᵀ``, and the Frobenius norm is
    transpose-invariant."""
    eye = jnp.eye(n, dtype=jnp.float32)
    zer = jnp.zeros((n, n), dtype=jnp.float32)
    m_re, m_im = bp_apply(theta, eye, zer, n, depth, use_pallas)
    t_re = target[0].T
    t_im = target[1].T
    return (jnp.sum((m_re - t_re) ** 2) + jnp.sum((m_im - t_im) ** 2)) / (n * n)


def adam_update(theta, m, v, g, t, lr):
    """One Adam step; constants must match ``opt::adam`` /
    ``runtime::engine`` on the Rust side."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = t + 1.0
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mhat = m2 / (1.0 - b1**step)
    vhat = v2 / (1.0 - b2**step)
    return theta - lr * mhat / (jnp.sqrt(vhat) + eps), m2, v2


def factorize_step(theta, m, v, t, lr, target, n: int, depth: int, use_pallas: bool = True):
    """Entry point: one fused loss+grad+Adam step.

    Shapes: ``theta/m/v [P]``, ``t/lr [1]``, ``target [2, N, N]`` →
    ``(theta' [P], m' [P], v' [P], loss [1])``."""
    loss, g = jax.value_and_grad(factorize_loss)(theta, target, n, depth, use_pallas)
    theta2, m2, v2 = adam_update(theta, m, v, g, t[0], lr[0])
    return theta2, m2, v2, jnp.reshape(loss, (1,))


# ---------------------------------------------------------------------
# Table-1 compression MLP (BPBP hidden layer, fixed bit-reversal perms)
# ---------------------------------------------------------------------

BIG_LOGIT = 30.0  # saturated gate == hard permutation


def mlp_theta_len(n: int, classes: int) -> int:
    return 2 * module_len(n) + n + classes * n + classes


def mlp_slices(n: int, classes: int):
    ml = module_len(n)
    o = 0
    sl = {}
    sl["mod0"] = slice(o, o + ml)
    o += ml
    sl["mod1"] = slice(o, o + ml)
    o += ml
    sl["bias"] = slice(o, o + n)
    o += n
    sl["w"] = slice(o, o + classes * n)
    o += classes * n
    sl["b"] = slice(o, o + classes)
    o += classes
    assert o == mlp_theta_len(n, classes)
    return sl


def mlp_trainable_mask(n: int, classes: int, real: bool = True) -> np.ndarray:
    """Static mask: fixed-permutation logits never move; for the real
    variant the imaginary twiddle planes never move either. Mirrors
    ``BpParams::trainable_mask``."""
    L = levels_of(n)
    mod_mask = np.ones(module_len(n), dtype=np.float32)
    off = 0
    for l in range(L):
        u = 1 << l
        if real:
            mod_mask[off + u * 4 : off + 2 * u * 4] = 0.0  # imag plane
        off += 2 * u * 4
    mod_mask[off : off + 3 * L] = 0.0  # logits frozen
    mask = np.concatenate(
        [
            mod_mask,
            mod_mask,
            np.ones(n, dtype=np.float32),
            np.ones(classes * n, dtype=np.float32),
            np.ones(classes, dtype=np.float32),
        ]
    )
    return mask


def bit_reversal(x, n: int):
    """Hard bit-reversal permutation along the last axis, expressed as a
    reshape + axis reversal (bit-reversal of 2^L indices == reversing the
    L binary axes) — no gather, and ~30× fewer HLO ops than the saturated
    relaxed-permutation machinery it replaces in fixed-perm graphs."""
    L = levels_of(n)
    B = x.shape[0]
    x = x.reshape((B,) + (2,) * L)
    x = x.transpose((0,) + tuple(range(L, 0, -1)))
    return x.reshape(B, n)


def bpbp_fixed_bitrev(theta2, x_re, x_im, n: int, use_pallas: bool):
    """Depth-2 BP stack with the permutations hardened to bit-reversal —
    the Table-1 configuration. Skips the relaxed-permutation gate stages
    entirely (their logits sit frozen at ±30 in theta)."""
    ml = module_len(n)
    level_fn = butterfly_level if use_pallas else butterfly_level_ref
    for d in range(2):
        levels, _logits = unpack_module(theta2[d * ml : (d + 1) * ml], n)
        x_re = bit_reversal(x_re, n)
        x_im = bit_reversal(x_im, n)
        for l, (tw_re, tw_im) in enumerate(levels):
            x_re, x_im = level_fn(x_re, x_im, tw_re, tw_im, l)
    return x_re, x_im


def mlp_logits_fn(theta, x, n: int, classes: int, use_pallas: bool = True):
    """Forward: BPBP hidden (real plane) + bias → ReLU → dense head."""
    sl = mlp_slices(n, classes)
    bp_theta = jnp.concatenate([theta[sl["mod0"]], theta[sl["mod1"]]])
    zeros = jnp.zeros_like(x)
    h_re, _ = bpbp_fixed_bitrev(bp_theta, x, zeros, n, use_pallas)
    a = jax.nn.relu(h_re + theta[sl["bias"]][None, :])
    w = theta[sl["w"]].reshape(classes, n)
    return a @ w.T + theta[sl["b"]][None, :]


def mlp_loss(theta, x, y_onehot, n: int, classes: int, use_pallas: bool = True):
    logits = mlp_logits_fn(theta, x, n, classes, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32))
    return loss, acc


def mlp_train_step(theta, vel, x, y_onehot, lr, mask, n: int, classes: int, use_pallas: bool = True):
    """Entry point: fused momentum-SGD step (momentum 0.9, Appendix C.2).

    Shapes: ``theta/vel/mask [P]``, ``x [B, N]``, ``y_onehot [B, C]``,
    ``lr [1]`` → ``(theta' [P], vel' [P], loss [1], acc [1])``.

    The trainable mask is an INPUT, not a baked constant: HLO *text* (the
    AOT interchange format) elides large constant literals, which the
    downstream parser then materializes as zeros — a baked-in mask
    silently froze every parameter. Callers pass
    ``mlp_trainable_mask(n, classes)`` (or the Rust equivalent)."""
    (loss, acc), g = jax.value_and_grad(mlp_loss, has_aux=True)(theta, x, y_onehot, n, classes, use_pallas)
    g = g * mask
    vel2 = 0.9 * vel + g
    theta2 = theta - lr[0] * vel2
    return theta2, vel2, jnp.reshape(loss, (1,)), jnp.reshape(acc, (1,))


def mlp_eval(theta, x, y_onehot, n: int, classes: int, use_pallas: bool = True):
    """Entry point: ``(loss [1], acc [1])`` on one batch."""
    loss, acc = mlp_loss(theta, x, y_onehot, n, classes, use_pallas)
    return jnp.reshape(loss, (1,)), jnp.reshape(acc, (1,))


# ---------------------------------------------------------------------
# reference initializer (mirrors BpParams::init + fix_bit_reversal) —
# used by python tests; the Rust side has its own.
# ---------------------------------------------------------------------


def init_module(n: int, rng: np.random.Generator, real: bool, fixed_bitrev: bool) -> np.ndarray:
    L = levels_of(n)
    parts = []
    std = math.sqrt(0.5) if real else 0.5
    for l in range(L):
        u = 1 << l
        re = rng.normal(0.0, std, size=(u, 2, 2)).astype(np.float32)
        im = (
            np.zeros((u, 2, 2), dtype=np.float32)
            if real
            else rng.normal(0.0, std, size=(u, 2, 2)).astype(np.float32)
        )
        parts.append(np.stack([re, im]).reshape(-1))
    logits = np.zeros((L, 3), dtype=np.float32)
    if fixed_bitrev:
        logits[:, 0] = BIG_LOGIT
        logits[:, 1:] = -BIG_LOGIT
    parts.append(logits.reshape(-1))
    return np.concatenate(parts)


def init_mlp_theta(n: int, classes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    mods = [init_module(n, rng, real=True, fixed_bitrev=True) for _ in range(2)]
    bias = np.zeros(n, dtype=np.float32)
    bound = math.sqrt(6.0 / n)
    w = rng.uniform(-bound, bound, size=(classes * n,)).astype(np.float32)
    b = np.zeros(classes, dtype=np.float32)
    return np.concatenate(mods + [bias, w, b])


# jitted convenience wrappers (used by tests; aot.py lowers explicitly)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def bp_apply_jit(theta, x, n, depth, use_pallas=True):
    return bp_apply_packed(theta, x, n, depth, use_pallas)


@functools.partial(jax.jit, static_argnums=(6, 7, 8))
def factorize_step_jit(theta, m, v, t, lr, target, n, depth, use_pallas=True):
    return factorize_step(theta, m, v, t, lr, target, n, depth, use_pallas)
