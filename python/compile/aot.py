"""AOT lowering: jax → HLO **text** → ``artifacts/`` + manifest.json.

Run once by ``make artifacts``; the Rust runtime
(``rust/src/runtime/engine.rs``) loads and compiles the results on the
PJRT CPU client. Python never runs at serve time.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

APPLY_BATCH = 16
MLP_N = 1024
MLP_BATCH = 50
MLP_EVAL_BATCH = 100
CLASSES = 10


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, shape):
    return {"name": name, "shape": list(shape)}


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def entry_bp_apply(n: int, depth: int):
    p = model.theta_len(n, depth)
    fn = functools.partial(model.bp_apply_packed, n=n, depth=depth, use_pallas=True)
    lowered = jax.jit(fn).lower(f32([p]), f32([2, APPLY_BATCH, n]))
    return {
        "name": f"bp_apply_n{n}_d{depth}",
        "lowered": lowered,
        "inputs": [spec("theta", [p]), spec("x", [2, APPLY_BATCH, n])],
        "outputs": [spec("y", [2, APPLY_BATCH, n])],
        "meta": {"n": n, "depth": depth, "batch": APPLY_BATCH},
    }


def entry_factorize_step(n: int, depth: int):
    p = model.theta_len(n, depth)
    fn = functools.partial(model.factorize_step, n=n, depth=depth, use_pallas=True)
    lowered = jax.jit(fn).lower(f32([p]), f32([p]), f32([p]), f32([1]), f32([1]), f32([2, n, n]))
    return {
        "name": f"factorize_step_n{n}_d{depth}",
        "lowered": lowered,
        "inputs": [
            spec("theta", [p]),
            spec("m", [p]),
            spec("v", [p]),
            spec("t", [1]),
            spec("lr", [1]),
            spec("target", [2, n, n]),
        ],
        "outputs": [spec("theta2", [p]), spec("m2", [p]), spec("v2", [p]), spec("loss", [1])],
        "meta": {"n": n, "depth": depth},
    }


def entry_mlp_train(n: int, batch: int, classes: int):
    p = model.mlp_theta_len(n, classes)
    fn = functools.partial(model.mlp_train_step, n=n, classes=classes, use_pallas=True)
    lowered = jax.jit(fn).lower(
        f32([p]), f32([p]), f32([batch, n]), f32([batch, classes]), f32([1]), f32([p])
    )
    return {
        "name": f"mlp_train_n{n}_b{batch}",
        "lowered": lowered,
        "inputs": [
            spec("theta", [p]),
            spec("vel", [p]),
            spec("x", [batch, n]),
            spec("y_onehot", [batch, classes]),
            spec("lr", [1]),
            spec("mask", [p]),
        ],
        "outputs": [spec("theta2", [p]), spec("vel2", [p]), spec("loss", [1]), spec("acc", [1])],
        "meta": {"n": n, "batch": batch, "classes": classes},
    }


def entry_mlp_eval(n: int, batch: int, classes: int):
    p = model.mlp_theta_len(n, classes)
    fn = functools.partial(model.mlp_eval, n=n, classes=classes, use_pallas=True)
    lowered = jax.jit(fn).lower(f32([p]), f32([batch, n]), f32([batch, classes]))
    return {
        "name": f"mlp_eval_n{n}_b{batch}",
        "lowered": lowered,
        "inputs": [spec("theta", [p]), spec("x", [batch, n]), spec("y_onehot", [batch, classes])],
        "outputs": [spec("loss", [1]), spec("acc", [1])],
        "meta": {"n": n, "batch": batch, "classes": classes},
    }


def build_entries(fast: bool):
    entries = []
    apply_ns = [8, 16, 64] if fast else [8, 16, 32, 64, 128, 256, 1024]
    for n in apply_ns:
        entries.append(entry_bp_apply(n, 1))
    entries.append(entry_bp_apply(16, 2))
    fac_ns = [8, 16] if fast else [8, 16, 32, 64]
    for n in fac_ns:
        entries.append(entry_factorize_step(n, 1))
    entries.append(entry_factorize_step(8, 2))
    if not fast:
        entries.append(entry_mlp_train(MLP_N, MLP_BATCH, CLASSES))
        entries.append(entry_mlp_eval(MLP_N, MLP_EVAL_BATCH, CLASSES))
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--fast", action="store_true", help="small entry set (CI/tests)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "entries": []}
    for e in build_entries(args.fast):
        t0 = time.time()
        text = to_hlo_text(e["lowered"])
        path = f"{e['name']}.hlo.txt"
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": e["name"],
                "path": path,
                "inputs": e["inputs"],
                "outputs": e["outputs"],
                "meta": e["meta"],
            }
        )
        print(
            f"[aot] {e['name']}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s",
            file=sys.stderr,
        )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['entries'])} entries to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
