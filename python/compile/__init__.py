"""AOT compilation layer: Pallas butterfly kernels (L1), the JAX BP
model (L2), and the HLO/manifest exporter consumed by the Rust runtime
(L3). See rust/src/runtime/engine.rs for the shared entry contracts."""
