"""Layer 1: the butterfly-level Pallas kernel.

One grid step processes one batch tile of a single butterfly level:
the ``[TB, N]`` planar tile is reshaped in-register to
``[TB, blocks, 2, half]`` and the pair exchange becomes an elementwise
complex FMA against the level's ``[half, 2, 2]`` twiddle tensor, which
stays resident in VMEM across the whole batch sweep.

HARDWARE ADAPTATION (the paper's kernel is CUDA): on GPU the authors
assign a threadblock per batch tile and stage twiddles in shared memory.
The TPU analogue implemented here: BlockSpec tiles the batch×N plane
into VMEM-resident blocks (full-N rows so a level's pair exchange stays
in-block), the twiddle operand is un-blocked (index_map pins it, so
Mosaic keeps it in VMEM across grid steps), and the 2×2-unit contraction
is expressed as reshape + elementwise FMA — a VPU workload, which is the
roofline-optimal form for this bandwidth-bound transform (no MXU matmul
is wasted on 2×2 tiles). See DESIGN.md §Hardware-Adaptation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU lowering is a compile-only target.

Autodiff: ``pallas_call`` has no AD rule, so the level is wrapped in a
``custom_vjp`` whose backward pass *reuses the same kernel* with the
adjoint twiddles (conj(G)ᵀ) — the butterfly's backward is itself a
butterfly — plus a jnp einsum for the twiddle cotangents.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import adjoint_twiddle

# Batch tile height. 64 rows × 1024 cols × 4 B × (re+im in & out + twiddle)
# ≈ 1.1 MiB — comfortably inside a TPU core's ~16 MiB VMEM with double
# buffering (see DESIGN.md §Hardware-Adaptation for the footprint table).
DEFAULT_TILE = 64


def _level_kernel(xr_ref, xi_ref, twr_ref, twi_ref, or_ref, oi_ref, *, half: int):
    xr = xr_ref[...]
    xi = xi_ref[...]
    tb, n = xr.shape
    blocks = n // (2 * half)
    xr = xr.reshape(tb, blocks, 2, half)
    xi = xi.reshape(tb, blocks, 2, half)
    twr = twr_ref[...]
    twi = twi_ref[...]
    lo_r, lo_i = xr[:, :, 0, :], xi[:, :, 0, :]
    hi_r, hi_i = xr[:, :, 1, :], xi[:, :, 1, :]

    def g(r, c):
        return twr[:, r, c][None, None, :], twi[:, r, c][None, None, :]

    def cmul(ar, ai, br, bi):
        return ar * br - ai * bi, ar * bi + ai * br

    g00r, g00i = g(0, 0)
    g01r, g01i = g(0, 1)
    g10r, g10i = g(1, 0)
    g11r, g11i = g(1, 1)
    a_r, a_i = cmul(g00r, g00i, lo_r, lo_i)
    b_r, b_i = cmul(g01r, g01i, hi_r, hi_i)
    c_r, c_i = cmul(g10r, g10i, lo_r, lo_i)
    d_r, d_i = cmul(g11r, g11i, hi_r, hi_i)
    or_ref[...] = jnp.stack([a_r + b_r, c_r + d_r], axis=2).reshape(tb, n)
    oi_ref[...] = jnp.stack([a_i + b_i, c_i + d_i], axis=2).reshape(tb, n)


def _tile(batch: int) -> int:
    if batch % DEFAULT_TILE == 0:
        return DEFAULT_TILE
    return batch  # single tile; interpret mode has no VMEM ceiling


def _level_pallas_raw(x_re, x_im, tw_re, tw_im, level: int):
    B, N = x_re.shape
    half = 1 << level
    tb = _tile(B)
    grid = (B // tb,)
    spec_x = pl.BlockSpec((tb, N), lambda i: (i, 0))
    # twiddles are un-blocked: same VMEM-resident operand for every tile
    spec_tw = pl.BlockSpec((half, 2, 2), lambda i: (0, 0, 0))
    out = pl.pallas_call(
        functools.partial(_level_kernel, half=half),
        grid=grid,
        in_specs=[spec_x, spec_x, spec_tw, spec_tw],
        out_specs=[spec_x, spec_x],
        out_shape=[jax.ShapeDtypeStruct((B, N), x_re.dtype)] * 2,
        interpret=True,
    )(x_re, x_im, tw_re, tw_im)
    return tuple(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def butterfly_level(x_re, x_im, tw_re, tw_im, level: int):
    """Differentiable butterfly level backed by the Pallas kernel."""
    return _level_pallas_raw(x_re, x_im, tw_re, tw_im, level)


def _fwd(x_re, x_im, tw_re, tw_im, level):
    y = _level_pallas_raw(x_re, x_im, tw_re, tw_im, level)
    return y, (x_re, x_im, tw_re, tw_im)


def _bwd(level, saved, ct):
    x_re, x_im, tw_re, tw_im, = saved
    dy_re, dy_im = ct
    # dx: the same butterfly kernel with adjoint twiddles conj(G)ᵀ.
    at_re, at_im = adjoint_twiddle(tw_re, tw_im)
    dx_re, dx_im = _level_pallas_raw(dy_re, dy_im, at_re, at_im, level)
    # dG = Σ_{batch, blocks} dy ⊗ conj(x), unit-tied — an einsum over the
    # blocked views (L2 graph code, not kernel code).
    B, N = x_re.shape
    half = 1 << level
    blocks = N // (2 * half)
    xr = x_re.reshape(B, blocks, 2, half)
    xi = x_im.reshape(B, blocks, 2, half)
    dr = dy_re.reshape(B, blocks, 2, half)
    di = dy_im.reshape(B, blocks, 2, half)
    # dg[r, c, u] = Σ dy[r] * conj(x[c]) (complex)
    dtw_re = jnp.einsum("bkru,bkcu->urc", dr, xr) + jnp.einsum("bkru,bkcu->urc", di, xi)
    dtw_im = jnp.einsum("bkru,bkcu->urc", di, xr) - jnp.einsum("bkru,bkcu->urc", dr, xi)
    return dx_re, dx_im, dtw_re, dtw_im


butterfly_level.defvjp(_fwd, _bwd)
