"""Pure-jnp oracle for the butterfly kernels.

Everything here is the *specification*: the Pallas kernel
(`kernels.butterfly`) and the Rust fast path must agree with these
functions bit-for-bit (up to fp32 reassociation). Used by pytest /
hypothesis and as the non-Pallas fallback in `model.py`.

Layout contract (mirrors ``rust/src/butterfly/params.rs``):

- batches are planar complex pairs ``(x_re, x_im)`` of shape ``[B, N]``;
- level ``l`` mixes pairs at distance ``2^l`` inside blocks of ``2^{l+1}``
  and is applied first for ``l = 0``;
- twiddles are factor-tied: level ``l`` has ``2^l`` units of shape
  ``[2, 2]``, shared across blocks, stored planar as
  ``(tw_re [U,2,2], tw_im [U,2,2])``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def cmul(ar, ai, br, bi):
    """Planar complex multiply."""
    return ar * br - ai * bi, ar * bi + ai * br


def butterfly_level_ref(x_re, x_im, tw_re, tw_im, level: int):
    """Apply one butterfly level to a planar batch ``[B, N]``.

    ``tw_*`` has shape ``[2^level, 2, 2]`` (factor-tied units).
    """
    B, N = x_re.shape
    half = 1 << level
    m = half * 2
    blocks = N // m
    xr = x_re.reshape(B, blocks, 2, half)
    xi = x_im.reshape(B, blocks, 2, half)
    lo_r, lo_i = xr[:, :, 0, :], xi[:, :, 0, :]
    hi_r, hi_i = xr[:, :, 1, :], xi[:, :, 1, :]

    def g(r, c):
        return tw_re[:, r, c][None, None, :], tw_im[:, r, c][None, None, :]

    g00r, g00i = g(0, 0)
    g01r, g01i = g(0, 1)
    g10r, g10i = g(1, 0)
    g11r, g11i = g(1, 1)
    a_r, a_i = cmul(g00r, g00i, lo_r, lo_i)
    b_r, b_i = cmul(g01r, g01i, hi_r, hi_i)
    c_r, c_i = cmul(g10r, g10i, lo_r, lo_i)
    d_r, d_i = cmul(g11r, g11i, hi_r, hi_i)
    out_r = jnp.stack([a_r + b_r, c_r + d_r], axis=2).reshape(B, N)
    out_i = jnp.stack([a_i + b_i, c_i + d_i], axis=2).reshape(B, N)
    return out_r, out_i


def adjoint_twiddle(tw_re, tw_im):
    """Twiddles of the backward (vjp) level: conj(G)ᵀ per unit."""
    return tw_re.transpose(0, 2, 1), -tw_im.transpose(0, 2, 1)


def generator_table(m: int, gate: int) -> np.ndarray:
    """Gather table of P^a / P^b / P^c on a block of size m
    (``out[i] = in[g[i]]``), matching
    ``rust/src/butterfly/permutation.rs``."""
    h = m // 2
    g = np.arange(m)
    if gate == 0:  # a: even-odd separation
        g[:h] = 2 * np.arange(h)
        g[h:] = 2 * np.arange(h) + 1
    elif gate == 1:  # b: reverse first half
        g[:h] = h - 1 - np.arange(h)
    elif gate == 2:  # c: reverse second half
        g[h:] = m - 1 - np.arange(h)
    else:
        raise ValueError(gate)
    return g


def _apply_generator(x, gate: int, m: int):
    """``x [B, blocks, m] → P^gate x`` via transpose/flip/concat only —
    NO gather. (xla_extension 0.5.1, which executes the AOT artifacts,
    mis-executes the gathers jnp fancy-indexing lowers to for some
    shapes; these primitives round-trip exactly. The even-odd separation
    P^a *is* a transpose: ``[m/2, 2] → [2, m/2]``.)"""
    h = m // 2
    if gate == 0:
        B, blocks, _ = x.shape
        return x.reshape(B, blocks, h, 2).transpose(0, 1, 3, 2).reshape(B, blocks, m)
    lo, hi = x[:, :, :h], x[:, :, h:]
    if gate == 1:
        return jnp.concatenate([lo[:, :, ::-1], hi], axis=2)
    return jnp.concatenate([lo, hi[:, :, ::-1]], axis=2)


def perm_step_ref(x_re, x_im, probs, step: int, n: int):
    """One relaxed permutation step (eq. (3)): three sigmoid gates at
    block size ``n >> step``, applied a → b → c."""
    m = n >> step
    blocks = n // m
    B = x_re.shape[0]
    for gate in range(3):
        p = probs[gate]
        xr = x_re.reshape(B, blocks, m)
        xi = x_im.reshape(B, blocks, m)
        x_re = (p * _apply_generator(xr, gate, m) + (1.0 - p) * xr).reshape(B, n)
        x_im = (p * _apply_generator(xi, gate, m) + (1.0 - p) * xi).reshape(B, n)
    return x_re, x_im


def bp_module_ref(x_re, x_im, levels_tw, logits, n: int, use_level=None):
    """One BP module: relaxed permutation then all butterfly levels.

    ``levels_tw`` is a list of ``(tw_re, tw_im)`` per level; ``logits``
    has shape ``[L, 3]``. ``use_level`` lets the caller substitute a
    different level implementation (e.g. the Pallas kernel)."""
    L = len(levels_tw)
    probs_all = 1.0 / (1.0 + jnp.exp(-logits))
    for k in range(L):
        x_re, x_im = perm_step_ref(x_re, x_im, probs_all[k], k, n)
    level_fn = use_level or butterfly_level_ref
    for l, (tw_re, tw_im) in enumerate(levels_tw):
        x_re, x_im = level_fn(x_re, x_im, tw_re, tw_im, l)
    return x_re, x_im
