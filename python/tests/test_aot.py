"""AOT path: lowering produces loadable HLO text and a schema-valid
manifest; parity between the pallas and ref lowerings."""

import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_produces_hlo_module():
    n, depth = 8, 1
    e = aot.entry_bp_apply(n, depth)
    text = aot.to_hlo_text(e["lowered"])
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # interpret-mode pallas must have lowered to plain HLO: no custom-call
    # to mosaic
    assert "tpu_custom_call" not in text


def test_entry_specs_are_consistent():
    for e in aot.build_entries(fast=True):
        assert e["name"]
        for s in e["inputs"] + e["outputs"]:
            assert all(isinstance(d, int) and d > 0 for d in s["shape"]), s


def test_fast_manifest_roundtrip(tmp_path):
    # run the module CLI end-to-end in fast mode
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--fast"],
        cwd=repo,
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["version"] == 1
    assert len(manifest["entries"]) >= 5
    for e in manifest["entries"]:
        p = tmp_path / e["path"]
        assert p.exists(), e["path"]
        head = p.read_text()[:200]
        assert head.startswith("HloModule")


def test_pallas_and_ref_lowerings_agree_numerically():
    # the *executed* outputs of the pallas graph and the pure-jnp graph
    # must match — this is the L1-inside-L2 integration check
    n, depth = 16, 1
    p = model.theta_len(n, depth)
    rng = np.random.default_rng(0)
    theta = rng.normal(size=p).astype(np.float32) * 0.5
    x = rng.normal(size=(2, aot.APPLY_BATCH, n)).astype(np.float32)
    y_pallas = model.bp_apply_jit(theta, x, n, depth, True)
    y_ref = model.bp_apply_jit(theta, x, n, depth, False)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


def test_factorize_entry_executes_under_jit():
    n, depth = 8, 1
    p = model.theta_len(n, depth)
    rng = np.random.default_rng(1)
    theta = rng.normal(size=p).astype(np.float32) * 0.5
    target = rng.normal(size=(2, n, n)).astype(np.float32)
    out = model.factorize_step_jit(
        theta,
        np.zeros(p, np.float32),
        np.zeros(p, np.float32),
        np.array([0.0], np.float32),
        np.array([0.01], np.float32),
        target,
        n,
        depth,
    )
    assert out[0].shape == (p,)
    assert float(out[3][0]) > 0.0
