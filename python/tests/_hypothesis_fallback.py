"""A tiny, deterministic stand-in for the `hypothesis` API surface these
tests use (`given`, `settings`, `strategies.integers`, `strategies.data`).

It is NOT a property-testing engine — no shrinking, no database, no
health checks. Each `@given` test is simply run `max_examples` times with
values drawn from a seeded PRNG, so failures are reproducible and the
suite stays runnable in environments where hypothesis cannot be
installed. When the real package is importable, `conftest.py` never
loads this module.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_BASE_SEED = 0xB77E4F1  # arbitrary fixed seed: runs are reproducible
_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a draw function over random.Random."""

    def __init__(self, draw_fn, is_data=False):
        self._draw_fn = draw_fn
        self.is_data = is_data

    def do_draw(self, rnd):
        return self._draw_fn(rnd)


def integers(min_value=None, max_value=None):
    lo = -(2**31) if min_value is None else min_value
    hi = 2**31 - 1 if max_value is None else max_value
    return _Strategy(lambda rnd: rnd.randint(lo, hi))


def booleans():
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def sampled_from(elements):
    items = list(elements)
    return _Strategy(lambda rnd: rnd.choice(items))


def lists(element, min_size=0, max_size=10, **_kw):
    def draw(rnd):
        size = rnd.randint(min_size, max_size)
        return [element.do_draw(rnd) for _ in range(size)]

    return _Strategy(draw)


class _DataObject:
    """Mirror of hypothesis' `data()` draw handle."""

    def __init__(self, rnd):
        self._rnd = rnd

    def draw(self, strategy, label=None):
        return strategy.do_draw(self._rnd)


def data():
    return _Strategy(None, is_data=True)


def given(*args, **kwargs):
    if args:
        raise TypeError("fallback @given supports keyword strategies only")
    strategies = kwargs

    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*wargs, **wkwargs):
            max_examples = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            for example in range(max_examples):
                rnd = random.Random(_BASE_SEED + example)
                drawn = {}
                for name, strat in strategies.items():
                    drawn[name] = _DataObject(rnd) if strat.is_data else strat.do_draw(rnd)
                try:
                    fn(*wargs, **wkwargs, **drawn)
                except BaseException:
                    # leave the original exception intact (pytest skips,
                    # assertion rewriting); just point at the example
                    print(f"falsifying example #{example}: {drawn!r}", file=sys.stderr)
                    raise

        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # pytest follows __wrapped__ when collecting fixture names and
        # would demand the strategy kwargs as fixtures; present the
        # wrapper as a zero-argument test instead.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return decorator


def settings(max_examples=None, deadline=None, **_kw):
    def decorator(fn):
        if max_examples is not None:
            fn._fallback_max_examples = max_examples
        return fn

    return decorator


def assume(condition):
    # No filtering engine: treat a failed assumption as a passed example.
    return bool(condition)


def install():
    """Register the shim as `hypothesis` / `hypothesis.strategies`."""
    h = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for mod in (st,):
        mod.integers = integers
        mod.booleans = booleans
        mod.floats = floats
        mod.sampled_from = sampled_from
        mod.lists = lists
        mod.data = data
    h.given = given
    h.settings = settings
    h.assume = assume
    h.strategies = st
    h.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    h.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = h
    sys.modules["hypothesis.strategies"] = st
