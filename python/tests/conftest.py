"""Test bootstrap: put `python/` on sys.path so `from compile import …`
resolves regardless of pytest's rootdir, and fall back to a minimal
deterministic `hypothesis` shim when the real package is absent (the
hermetic image has no pip access; CI installs the real one)."""

import os
import sys

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_PYTHON_DIR = os.path.dirname(_TESTS_DIR)
for p in (_TESTS_DIR, _PYTHON_DIR):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401  (prefer the real thing when present)
except ModuleNotFoundError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
