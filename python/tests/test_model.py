"""L2 correctness: BP apply vs dense reconstruction / closed forms,
factorization objective + fused Adam step, MLP train/eval graphs."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import generator_table


def rand_theta(n, depth, seed=0, hard_perm=False):
    rng = np.random.default_rng(seed)
    mods = []
    for _ in range(depth):
        m = model.init_module(n, rng, real=False, fixed_bitrev=hard_perm)
        if not hard_perm:
            # random soft logits
            L = model.levels_of(n)
            m[-3 * L :] = rng.normal(0, 1, size=3 * L).astype(np.float32)
        mods.append(m)
    return np.concatenate(mods)


def dense_from_apply(theta, n, depth, use_pallas=True):
    """Reconstruct M by applying to identity rows (returns Mᵀ rows)."""
    eye = np.eye(n, dtype=np.float32)
    zer = np.zeros((n, n), dtype=np.float32)
    m_re, m_im = model.bp_apply(jnp.asarray(theta), eye, zer, n, depth, use_pallas)
    return np.asarray(m_re).T + 1j * np.asarray(m_im).T


def test_theta_len_matches_rust_contract():
    # BpParams::data: 2·(4N−4) twiddles + 3L logits
    for n in [8, 16, 64, 1024]:
        L = int(math.log2(n))
        assert model.module_len(n) == 2 * (4 * n - 4) + 3 * L


@settings(max_examples=10, deadline=None)
@given(log_n=st.integers(min_value=2, max_value=5), seed=st.integers(0, 2**31 - 1))
def test_apply_is_linear_operator(log_n, seed):
    n = 1 << log_n
    theta = rand_theta(n, 1, seed)
    m = dense_from_apply(theta, n, 1)
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=n).astype(np.float32) + 1j * rng.normal(size=n).astype(np.float32)
    y_re, y_im = model.bp_apply(
        jnp.asarray(theta),
        x.real[None, :].astype(np.float32),
        x.imag[None, :].astype(np.float32),
        n,
        1,
    )
    got = np.asarray(y_re)[0] + 1j * np.asarray(y_im)[0]
    np.testing.assert_allclose(got, m @ x, rtol=1e-3, atol=1e-3)


def dft_theta(n):
    """Closed-form DFT theta (mirrors rust closed_form::dft_stack)."""
    L = model.levels_of(n)
    parts = []
    s = math.sqrt(0.5)
    for l in range(L):
        u = 1 << l
        m = 1 << (l + 1)
        re = np.zeros((u, 2, 2), dtype=np.float32)
        im = np.zeros((u, 2, 2), dtype=np.float32)
        for j in range(u):
            w = np.exp(-2j * np.pi * j / m)
            re[j] = [[s, s * w.real], [s, -s * w.real]]
            im[j] = [[0, s * w.imag], [0, -s * w.imag]]
        parts.append(np.stack([re, im]).reshape(-1))
    logits = np.zeros((L, 3), dtype=np.float32)
    logits[:, 0] = model.BIG_LOGIT
    logits[:, 1:] = -model.BIG_LOGIT
    parts.append(logits.reshape(-1))
    return np.concatenate(parts)


@pytest.mark.parametrize("n", [4, 8, 32])
def test_closed_form_dft_theta_is_the_unitary_dft(n):
    m = dense_from_apply(dft_theta(n), n, 1)
    k, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    want = np.exp(-2j * np.pi * k * j / n) / math.sqrt(n)
    np.testing.assert_allclose(m, want, atol=2e-5)


def test_all_a_gates_compose_to_bit_reversal():
    n = 16
    L = 4
    # identity twiddles + saturated-a logits ⇒ pure bit-reversal operator
    parts = []
    for l in range(L):
        u = 1 << l
        re = np.tile(np.eye(2, dtype=np.float32), (u, 1, 1))
        parts.append(np.stack([re, np.zeros_like(re)]).reshape(-1))
    logits = np.zeros((L, 3), dtype=np.float32)
    logits[:, 0] = model.BIG_LOGIT
    logits[:, 1:] = -model.BIG_LOGIT
    parts.append(logits.reshape(-1))
    theta = np.concatenate(parts)
    m = dense_from_apply(theta, n, 1).real
    # bit-reversal permutation matrix
    def rev(i):
        return int(format(i, f"0{4}b")[::-1], 2)
    want = np.zeros((n, n))
    for i in range(n):
        want[i, rev(i)] = 1.0
    np.testing.assert_allclose(m, want, atol=1e-6)


def test_factorize_step_descends_and_matches_loss():
    n, depth = 8, 1
    theta = rand_theta(n, depth, 3)
    p = theta.size
    k, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    f = np.exp(-2j * np.pi * k * j / n) / math.sqrt(n)
    target = np.stack([f.real, f.imag]).astype(np.float32)
    m = np.zeros(p, dtype=np.float32)
    v = np.zeros(p, dtype=np.float32)
    losses = []
    for step in range(40):
        theta, m, v, loss = model.factorize_step_jit(
            theta,
            m,
            v,
            np.array([float(step)], np.float32),
            np.array([0.05], np.float32),
            target,
            n,
            depth,
        )
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.5, losses[::10]
    # reported loss matches the objective recomputed from scratch
    direct = float(model.factorize_loss(theta, jnp.asarray(target), n, depth))
    # (losses[-1] was computed pre-update; just check the trend + finite)
    assert math.isfinite(direct)


def test_adam_update_matches_reference_formula():
    rng = np.random.default_rng(5)
    theta = rng.normal(size=7).astype(np.float32)
    g = rng.normal(size=7).astype(np.float32)
    m = np.zeros(7, np.float32)
    v = np.zeros(7, np.float32)
    t2, m2, v2 = model.adam_update(theta, m, v, g, 0.0, 0.01)
    # first step: theta − lr·g/(|g| + ε·√(1−b2)) ≈ theta − lr·sign(g)
    np.testing.assert_allclose(np.asarray(t2), theta - 0.01 * np.sign(g), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m2), 0.1 * g, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(v2), 0.001 * g * g, rtol=1e-4)


def test_mlp_shapes_and_mask():
    n, c = 16, 4
    p = model.mlp_theta_len(n, c)
    theta = model.init_mlp_theta(n, c, seed=1)
    assert theta.size == p
    mask = model.mlp_trainable_mask(n, c)
    sl = model.mlp_slices(n, c)
    # logits frozen in both modules
    L = model.levels_of(n)
    assert mask[sl["mod0"]][-3 * L :].sum() == 0
    # imag planes frozen (real variant): half the twiddle scalars
    assert mask[sl["mod0"]][: -3 * L].sum() == (model.module_len(n) - 3 * L) / 2
    # head fully trainable
    assert mask[sl["w"]].min() == 1.0


def test_mlp_train_step_learns_tiny_task():
    n, c, b = 16, 4, 8
    theta = model.init_mlp_theta(n, c, seed=2)
    vel = np.zeros_like(theta)
    rng = np.random.default_rng(3)
    # class = argmax over 4 fixed random projections
    proj = rng.normal(size=(c, n)).astype(np.float32)
    losses = []
    for step in range(60):
        x = rng.normal(size=(b, n)).astype(np.float32)
        y = np.argmax(x @ proj.T, axis=1)
        yo = np.eye(c, dtype=np.float32)[y]
        theta, vel, loss, acc = model.mlp_train_step(
            theta, vel, x, yo, np.array([0.05], np.float32), model.mlp_trainable_mask(n, c), n, c
        )
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    # logits slice unchanged (fixed permutation)
    sl = model.mlp_slices(n, c)
    L = model.levels_of(n)
    np.testing.assert_array_equal(
        np.asarray(theta)[sl["mod0"]][-3 * L :],
        model.init_mlp_theta(n, c, seed=2)[sl["mod0"]][-3 * L :],
    )


def test_perm_generator_consistency_with_rust():
    # spot values that the rust tests also assert
    assert list(generator_table(8, 0)) == [0, 2, 4, 6, 1, 3, 5, 7]
    assert list(generator_table(8, 1)) == [3, 2, 1, 0, 4, 5, 6, 7]
    assert list(generator_table(8, 2)) == [0, 1, 2, 3, 7, 6, 5, 4]
