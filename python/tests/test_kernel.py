"""L1 correctness: the Pallas butterfly level vs the pure-jnp oracle,
swept over shapes with hypothesis, plus custom-vjp gradient checks."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.butterfly import butterfly_level
from compile.kernels.ref import adjoint_twiddle, butterfly_level_ref, generator_table


def rand_level(rng, batch, n, level):
    half = 1 << level
    x_re = rng.normal(size=(batch, n)).astype(np.float32)
    x_im = rng.normal(size=(batch, n)).astype(np.float32)
    tw_re = rng.normal(size=(half, 2, 2)).astype(np.float32)
    tw_im = rng.normal(size=(half, 2, 2)).astype(np.float32)
    return x_re, x_im, tw_re, tw_im


@settings(max_examples=25, deadline=None)
@given(
    log_n=st.integers(min_value=1, max_value=7),
    batch=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    data=st.data(),
)
def test_pallas_matches_ref(log_n, batch, seed, data):
    n = 1 << log_n
    level = data.draw(st.integers(min_value=0, max_value=log_n - 1))
    rng = np.random.default_rng(seed)
    x_re, x_im, tw_re, tw_im = rand_level(rng, batch, n, level)
    got_r, got_i = butterfly_level(x_re, x_im, tw_re, tw_im, level)
    want_r, want_i = butterfly_level_ref(x_re, x_im, tw_re, tw_im, level)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


def test_tiled_batch_matches_single_tile():
    # batch 128 = 2 tiles of 64: tiling must be invisible
    rng = np.random.default_rng(3)
    n, level = 32, 3
    x_re, x_im, tw_re, tw_im = rand_level(rng, 128, n, level)
    got_r, got_i = butterfly_level(x_re, x_im, tw_re, tw_im, level)
    want_r, want_i = butterfly_level_ref(x_re, x_im, tw_re, tw_im, level)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


def test_identity_twiddle_is_identity():
    n, level = 16, 2
    half = 1 << level
    x_re = np.arange(n, dtype=np.float32)[None, :]
    x_im = np.zeros((1, n), dtype=np.float32)
    tw_re = np.tile(np.eye(2, dtype=np.float32), (half, 1, 1))
    tw_im = np.zeros((half, 2, 2), dtype=np.float32)
    y_re, y_im = butterfly_level(x_re, x_im, tw_re, tw_im, level)
    np.testing.assert_allclose(y_re, x_re, atol=1e-6)
    np.testing.assert_allclose(y_im, 0.0, atol=1e-6)


@pytest.mark.parametrize("level", [0, 1, 2])
def test_custom_vjp_matches_autodiff_of_ref(level):
    rng = np.random.default_rng(7)
    n, batch = 8, 3
    x_re, x_im, tw_re, tw_im = rand_level(rng, batch, n, level)

    def loss_pallas(args):
        yr, yi = butterfly_level(args[0], args[1], args[2], args[3], level)
        return jnp.sum(yr**2) + 0.5 * jnp.sum(yi**2)

    def loss_ref(args):
        yr, yi = butterfly_level_ref(args[0], args[1], args[2], args[3], level)
        return jnp.sum(yr**2) + 0.5 * jnp.sum(yi**2)

    args = (jnp.asarray(x_re), jnp.asarray(x_im), jnp.asarray(tw_re), jnp.asarray(tw_im))
    g_pallas = jax.grad(loss_pallas)(args)
    g_ref = jax.grad(loss_ref)(args)
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=1e-4, atol=1e-4)


def test_adjoint_twiddle_is_conj_transpose():
    rng = np.random.default_rng(9)
    tw_re = rng.normal(size=(4, 2, 2)).astype(np.float32)
    tw_im = rng.normal(size=(4, 2, 2)).astype(np.float32)
    at_re, at_im = adjoint_twiddle(tw_re, tw_im)
    g = tw_re[0] + 1j * tw_im[0]
    a = at_re[0] + 1j * at_im[0]
    np.testing.assert_allclose(a, g.conj().T, atol=1e-6)


def test_generator_tables_match_paper_examples():
    # P^a: [0,1,2,3] → [0,2,1,3]; P^b reverses first half; P^c second.
    x = np.array([0, 1, 2, 3])
    assert list(x[generator_table(4, 0)]) == [0, 2, 1, 3]
    assert list(x[generator_table(4, 1)]) == [1, 0, 2, 3]
    assert list(x[generator_table(4, 2)]) == [0, 1, 3, 2]


def test_level_is_linear_in_x():
    rng = np.random.default_rng(11)
    n, level = 16, 1
    x1 = rand_level(rng, 2, n, level)
    x2_re = rng.normal(size=(2, n)).astype(np.float32)
    x2_im = rng.normal(size=(2, n)).astype(np.float32)
    a = np.float32(1.7)
    y_sum_r, y_sum_i = butterfly_level(x1[0] * a + x2_re, x1[1] * a + x2_im, x1[2], x1[3], level)
    y1r, y1i = butterfly_level(x1[0], x1[1], x1[2], x1[3], level)
    y2r, y2i = butterfly_level(x2_re, x2_im, x1[2], x1[3], level)
    np.testing.assert_allclose(y_sum_r, a * np.asarray(y1r) + np.asarray(y2r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_sum_i, a * np.asarray(y1i) + np.asarray(y2i), rtol=2e-4, atol=2e-4)
